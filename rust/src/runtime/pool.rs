//! Dependency-free scoped-thread node pool for the per-node hot path.
//!
//! `NodePool` owns `threads − 1` persistent OS workers plus the calling
//! thread. [`NodePool::run_chunks`] partitions the node index range
//! `0..n` into at most `threads` **contiguous, deterministically chosen**
//! chunks and executes a borrowed closure on each, blocking until every
//! chunk finishes. Dispatch reuses the same parked workers for the whole
//! pool lifetime, so the steady-state cost per dispatch is one mutex
//! round-trip and a condvar wake — no thread spawns, no heap allocation.
//!
//! # Determinism contract
//!
//! Results are **bitwise identical for every thread count**, because the
//! pool only ever parallelizes *across nodes*:
//!
//! * chunk boundaries depend only on `(n, threads)` — chunk `c` covers
//!   `[c·n/t, (c+1)·n/t)` — and each index is processed by exactly one
//!   chunk, so the node → work assignment is a pure function of the
//!   inputs (which thread runs a chunk is irrelevant to the output);
//! * callers must (and in this crate do) perform **no cross-node
//!   reductions** inside a dispatch: every chunk writes only its own
//!   disjoint slice elements ([`DisjointSlice`]) and reads shared inputs
//!   immutably, so no floating-point reduction order ever changes.
//!
//! With `threads = 1` (the default) nothing is spawned and `run_chunks`
//! degenerates to a plain serial loop — byte-for-byte the serial path.

use std::marker::PhantomData;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Work shared between the coordinator and the workers for one dispatch.
struct JobSlot {
    /// Monotonic dispatch counter; workers wake when it advances.
    epoch: u64,
    /// The borrowed chunk closure, lifetime-erased for the dispatch
    /// duration (cleared before `run_chunks` returns).
    job: Option<&'static (dyn Fn(usize, usize) + Sync)>,
    /// Total chunks and the next unclaimed chunk index for this epoch.
    chunks: usize,
    next: usize,
    /// Items covered by this dispatch (chunk bounds derive from this).
    items: usize,
    /// Workers that have not yet finished the current epoch.
    active: usize,
    /// Set when a worker's chunk panicked; the coordinator re-raises.
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    slot: Mutex<JobSlot>,
    go: Condvar,
    done: Condvar,
}

/// Persistent worker pool; see the module docs for the contract.
pub struct NodePool {
    threads: usize,
    shared: Option<Arc<Shared>>,
    handles: Vec<JoinHandle<()>>,
}

/// Deterministic chunk bounds: chunk `c` of `t` over `n` items.
#[inline]
fn chunk_bounds(n: usize, t: usize, c: usize) -> (usize, usize) {
    (c * n / t, (c + 1) * n / t)
}

impl NodePool {
    /// A pool using `threads` OS threads in total (the caller counts as
    /// one). `threads <= 1` spawns nothing and runs everything serially.
    pub fn new(threads: usize) -> NodePool {
        let threads = threads.max(1);
        if threads == 1 {
            return NodePool { threads, shared: None, handles: Vec::new() };
        }
        let shared = Arc::new(Shared {
            slot: Mutex::new(JobSlot {
                epoch: 0,
                job: None,
                chunks: 0,
                next: 0,
                items: 0,
                active: 0,
                panicked: false,
                shutdown: false,
            }),
            go: Condvar::new(),
            done: Condvar::new(),
        });
        let mut handles = Vec::with_capacity(threads - 1);
        for w in 0..threads - 1 {
            let sh = Arc::clone(&shared);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("dpsa-node-pool-{w}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawn pool worker"),
            );
        }
        NodePool { threads, shared: Some(shared), handles }
    }

    /// Serial pool (no workers) — the `threads = 1` path.
    pub fn serial() -> NodePool {
        NodePool::new(1)
    }

    /// Total threads this pool uses, including the calling thread.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Partition `0..n` into deterministic contiguous chunks and run
    /// `f(lo, hi)` for each, in parallel across the pool. Blocks until
    /// all chunks complete. `f` may borrow from the caller's stack.
    pub fn run_chunks<F: Fn(usize, usize) + Sync>(&self, n: usize, f: &F) {
        if n == 0 {
            return;
        }
        let t = self.threads.min(n);
        let shared = match &self.shared {
            Some(s) if t > 1 => s,
            _ => {
                f(0, n);
                return;
            }
        };
        // SAFETY: the reference is only reachable through the job slot,
        // every worker finishes using it before decrementing `active`,
        // and we clear the slot (under the lock) before returning — so
        // the erased reference never outlives this call frame.
        let wide: &(dyn Fn(usize, usize) + Sync) = f;
        let erased: &'static (dyn Fn(usize, usize) + Sync) =
            unsafe { std::mem::transmute(wide) };
        let workers = self.handles.len();
        {
            let mut s = shared.slot.lock().unwrap();
            s.job = Some(erased);
            s.chunks = t;
            s.items = n;
            s.next = 0;
            s.active = workers;
            s.panicked = false;
            s.epoch = s.epoch.wrapping_add(1);
        }
        shared.go.notify_all();
        // The caller participates in the chunk race like any worker. A
        // panic in `f` is caught and re-raised only after every worker
        // has finished the epoch — `f` must never be reachable once this
        // frame unwinds (that is what makes the lifetime erasure sound).
        let mut caller_panic: Option<Box<dyn std::any::Any + Send>> = None;
        loop {
            let mut s = shared.slot.lock().unwrap();
            if s.next >= s.chunks {
                break;
            }
            let c = s.next;
            s.next += 1;
            let (chunks, items) = (s.chunks, s.items);
            drop(s);
            let (lo, hi) = chunk_bounds(items, chunks, c);
            if caller_panic.is_none() {
                if let Err(p) =
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(lo, hi)))
                {
                    caller_panic = Some(p);
                }
            }
        }
        let mut s = shared.slot.lock().unwrap();
        while s.active > 0 {
            s = shared.done.wait(s).unwrap();
        }
        s.job = None;
        let worker_panicked = s.panicked;
        drop(s);
        if let Some(p) = caller_panic {
            std::panic::resume_unwind(p);
        }
        if worker_panicked {
            panic!("node-pool worker panicked during dispatch");
        }
    }
}

fn worker_loop(shared: &Shared) {
    let mut seen = 0u64;
    loop {
        let mut s = shared.slot.lock().unwrap();
        while s.epoch == seen && !s.shutdown {
            s = shared.go.wait(s).unwrap();
        }
        if s.shutdown {
            return;
        }
        seen = s.epoch;
        loop {
            if s.next >= s.chunks {
                break;
            }
            let c = s.next;
            s.next += 1;
            let (chunks, items) = (s.chunks, s.items);
            let f = s.job.expect("job present during epoch");
            drop(s);
            let (lo, hi) = chunk_bounds(items, chunks, c);
            // Catch panics so the epoch barrier always completes; the
            // coordinator re-raises after the dispatch drains.
            let panicked =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(lo, hi))).is_err();
            s = shared.slot.lock().unwrap();
            if panicked {
                s.panicked = true;
            }
        }
        s.active -= 1;
        if s.active == 0 {
            shared.done.notify_all();
        }
        drop(s);
    }
}

impl Drop for NodePool {
    fn drop(&mut self) {
        if let Some(shared) = &self.shared {
            if let Ok(mut s) = shared.slot.lock() {
                s.shutdown = true;
            }
            shared.go.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl std::fmt::Debug for NodePool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "NodePool {{ threads: {} }}", self.threads)
    }
}

/// A shared wrapper over a mutable slice allowing **disjoint** per-index
/// writes from multiple pool chunks.
///
/// The borrow checker cannot see that parallel chunks write disjoint
/// elements, so element access is an `unsafe fn`: the caller must
/// guarantee that while a dispatch is in flight, each index is accessed
/// by at most one chunk (the contiguous-chunk partition of `run_chunks`
/// gives this for free when chunk `c` only touches indices in
/// `[lo, hi)`).
pub struct DisjointSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Send for DisjointSlice<'_, T> {}
unsafe impl<T: Send> Sync for DisjointSlice<'_, T> {}

impl<'a, T> DisjointSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> DisjointSlice<'a, T> {
        DisjointSlice { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Mutable access to element `i`.
    ///
    /// # Safety
    /// No other chunk may concurrently access index `i`, and `i` must be
    /// in bounds (checked by an assert).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self, i: usize) -> &mut T {
        assert!(i < self.len, "DisjointSlice index {i} out of bounds ({})", self.len);
        &mut *self.ptr.add(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_range_exactly_once() {
        for &(n, t) in &[(1usize, 4usize), (7, 3), (20, 4), (4, 8), (100, 1), (13, 13)] {
            let mut seen = vec![0u32; n];
            let mut c = 0;
            let tt = t.min(n);
            while c < tt {
                let (lo, hi) = chunk_bounds(n, tt, c);
                for s in seen[lo..hi].iter_mut() {
                    *s += 1;
                }
                c += 1;
            }
            assert!(seen.iter().all(|&s| s == 1), "n={n} t={t} seen={seen:?}");
        }
    }

    #[test]
    fn parallel_map_matches_serial() {
        let pool = NodePool::new(4);
        let n = 103;
        let mut out = vec![0.0f64; n];
        {
            let d = DisjointSlice::new(&mut out);
            pool.run_chunks(n, &|lo, hi| {
                for i in lo..hi {
                    // SAFETY: each index belongs to exactly one chunk.
                    unsafe { *d.get_mut(i) = (i as f64).sqrt() * 3.0 };
                }
            });
        }
        let serial: Vec<f64> = (0..n).map(|i| (i as f64).sqrt() * 3.0).collect();
        assert_eq!(out, serial); // bitwise: same per-element computation
    }

    #[test]
    fn every_index_processed_once_under_contention() {
        let pool = NodePool::new(4);
        for round in 0..50 {
            let n = 1 + (round * 7) % 64;
            let counter = AtomicUsize::new(0);
            pool.run_chunks(n, &|lo, hi| {
                counter.fetch_add(hi - lo, Ordering::Relaxed);
            });
            assert_eq!(counter.load(Ordering::Relaxed), n, "round={round}");
        }
    }

    #[test]
    fn serial_pool_runs_inline() {
        let pool = NodePool::serial();
        assert_eq!(pool.threads(), 1);
        let hits = AtomicUsize::new(0);
        pool.run_chunks(10, &|lo, hi| {
            assert_eq!((lo, hi), (0, 10));
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn zero_items_is_noop() {
        let pool = NodePool::new(2);
        pool.run_chunks(0, &|_, _| panic!("must not be called"));
    }

    #[test]
    fn panics_propagate_without_deadlock() {
        let pool = NodePool::new(3);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_chunks(8, &|lo, _hi| {
                if lo == 0 {
                    panic!("boom");
                }
            });
        }));
        assert!(result.is_err());
        // The pool stays usable after a panicked dispatch.
        let total = AtomicUsize::new(0);
        pool.run_chunks(5, &|lo, hi| {
            total.fetch_add(hi - lo, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn pool_survives_many_dispatches() {
        let pool = NodePool::new(3);
        let total = AtomicUsize::new(0);
        for _ in 0..500 {
            pool.run_chunks(11, &|lo, hi| {
                total.fetch_add(hi - lo, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 500 * 11);
    }
}
