//! Persistent workspaces for the zero-allocation steady state.
//!
//! Every distributed iteration in the paper — S-DOT/SA-DOT outer steps,
//! F-DOT's two consensus phases, the baselines' mixing/gradient loops —
//! repeats the same shapes thousands of times. The seed implementation
//! reallocated each intermediate on every call; these workspaces are
//! allocated once at warm-up and reused, so after the first outer
//! iteration the hot loops perform **zero heap allocations** (verified
//! by `bench_hotpath`'s counting allocator).
//!
//! Two layers:
//!
//! * [`ConsensusWorkspace`] — owned by `SyncNetwork`: the synchronous
//!   double buffer for mixing rounds plus the push-sum scalar channel.
//!   `Mat::reshape_in_place` never shrinks capacity, so alternating
//!   message shapes (e.g. F-DOT's `n×r` then `r×r`) stay allocation-free
//!   once the largest shape has been seen.
//! * [`NodeScratch`] — one per node, owned by algorithm runners: general
//!   matrix temporaries plus a QR scratch. Each node's scratch is only
//!   ever touched by the pool chunk that owns that node, preserving the
//!   determinism contract in [`crate::runtime::pool`].

use crate::linalg::qr::QrScratch;
use crate::linalg::Mat;

/// Double buffer + push-sum scalar channel for consensus mixing rounds.
#[derive(Debug, Default)]
pub struct ConsensusWorkspace {
    /// Per-node destination buffer for one synchronous mixing round.
    pub next: Vec<Mat>,
    /// Push-sum weight channel (source) — `ratio_consensus_sum` only.
    pub w_src: Vec<f64>,
    /// Push-sum weight channel (destination).
    pub w_dst: Vec<f64>,
}

impl ConsensusWorkspace {
    pub fn new() -> ConsensusWorkspace {
        ConsensusWorkspace::default()
    }

    /// Shape the double buffer to match the per-node matrices in `z`,
    /// reusing existing capacity.
    pub fn ensure_mats(&mut self, z: &[Mat]) {
        if self.next.len() != z.len() {
            self.next.resize_with(z.len(), || Mat::zeros(0, 0));
        }
        for (buf, m) in self.next.iter_mut().zip(z.iter()) {
            buf.reshape_in_place(m.rows, m.cols);
        }
    }

    /// Reset the scalar channels for a push-sum run over `n` nodes.
    pub fn ensure_scalars(&mut self, n: usize, init: f64) {
        self.w_src.clear();
        self.w_src.resize(n, init);
        self.w_dst.clear();
        self.w_dst.resize(n, 0.0);
    }
}

/// Per-node scratch matrices for algorithm runners.
///
/// The fields are deliberately generic temporaries: `*_into` kernels
/// shape them on first use and reuse the capacity afterwards.
#[derive(Debug, Default)]
pub struct NodeScratch {
    pub t0: Mat,
    pub t1: Mat,
    pub t2: Mat,
    pub qr: QrScratch,
}

impl NodeScratch {
    pub fn new() -> NodeScratch {
        NodeScratch::default()
    }
}

/// Allocate one scratch per node (the runner-side workspace).
pub fn node_scratch(n: usize) -> Vec<NodeScratch> {
    let mut v = Vec::with_capacity(n);
    v.resize_with(n, NodeScratch::new);
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ensure_mats_tracks_shapes_and_reuses_capacity() {
        let mut ws = ConsensusWorkspace::new();
        let z: Vec<Mat> = (0..3).map(|_| Mat::zeros(10, 4)).collect();
        ws.ensure_mats(&z);
        assert_eq!(ws.next.len(), 3);
        assert_eq!((ws.next[0].rows, ws.next[0].cols), (10, 4));
        let cap_before = ws.next[0].data.capacity();
        // Shrink then grow back: capacity must be retained (no realloc).
        let small: Vec<Mat> = (0..3).map(|_| Mat::zeros(2, 2)).collect();
        ws.ensure_mats(&small);
        assert_eq!((ws.next[1].rows, ws.next[1].cols), (2, 2));
        ws.ensure_mats(&z);
        assert!(ws.next[0].data.capacity() >= cap_before);
    }

    #[test]
    fn ensure_scalars_resets_values() {
        let mut ws = ConsensusWorkspace::new();
        ws.ensure_scalars(4, 0.25);
        assert_eq!(ws.w_src, vec![0.25; 4]);
        ws.w_src[2] = 9.0;
        ws.ensure_scalars(4, 0.25);
        assert_eq!(ws.w_src, vec![0.25; 4]);
    }

    #[test]
    fn node_scratch_sized() {
        let s = node_scratch(5);
        assert_eq!(s.len(), 5);
    }
}
