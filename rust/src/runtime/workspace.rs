//! Persistent workspaces for the zero-allocation steady state.
//!
//! Every distributed iteration in the paper — S-DOT/SA-DOT outer steps,
//! F-DOT's two consensus phases, the baselines' mixing/gradient loops —
//! repeats the same shapes thousands of times. The seed implementation
//! reallocated each intermediate on every call; these workspaces are
//! allocated once at warm-up and reused, so after the first outer
//! iteration the hot loops perform **zero heap allocations** (verified
//! by `bench_hotpath`'s counting allocator).
//!
//! Two layers:
//!
//! * [`ConsensusWorkspace`] — owned by `SyncNetwork`: the synchronous
//!   double buffer for mixing rounds plus the push-sum scalar channel.
//!   `Mat::reshape_in_place` never shrinks capacity, so alternating
//!   message shapes (e.g. F-DOT's `n×r` then `r×r`) stay allocation-free
//!   once the largest shape has been seen.
//! * [`NodeScratch`] — one per node, owned by algorithm runners: general
//!   matrix temporaries plus a QR scratch. Each node's scratch is only
//!   ever touched by the pool chunk that owns that node, preserving the
//!   determinism contract in [`crate::runtime::pool`].

use crate::linalg::qr::QrScratch;
use crate::linalg::Mat;
use std::marker::PhantomData;

/// Double buffer + push-sum scalar channel for consensus mixing rounds.
#[derive(Debug, Default)]
pub struct ConsensusWorkspace {
    /// Per-node destination buffer for one synchronous mixing round.
    pub next: Vec<Mat>,
    /// Push-sum weight channel (source) — `ratio_consensus_sum` only.
    pub w_src: Vec<f64>,
    /// Push-sum weight channel (destination).
    pub w_dst: Vec<f64>,
    /// Raw-view table for the two-level mixing dispatch (refilled each
    /// round without allocating).
    pub mat_views: MatRowsScratch,
}

impl ConsensusWorkspace {
    pub fn new() -> ConsensusWorkspace {
        ConsensusWorkspace::default()
    }

    /// Shape the double buffer to match the per-node matrices in `z`,
    /// reusing existing capacity.
    pub fn ensure_mats(&mut self, z: &[Mat]) {
        if self.next.len() != z.len() {
            self.next.resize_with(z.len(), || Mat::zeros(0, 0));
        }
        for (buf, m) in self.next.iter_mut().zip(z.iter()) {
            buf.reshape_in_place(m.rows, m.cols);
        }
    }

    /// Reset the scalar channels for a push-sum run over `n` nodes.
    pub fn ensure_scalars(&mut self, n: usize, init: f64) {
        self.w_src.clear();
        self.w_src.resize(n, init);
        self.w_dst.clear();
        self.w_dst.resize(n, 0.0);
    }
}

/// Per-node scratch matrices for algorithm runners.
///
/// The fields are deliberately generic temporaries: `*_into` kernels
/// shape them on first use and reuse the capacity afterwards.
#[derive(Debug, Default)]
pub struct NodeScratch {
    pub t0: Mat,
    pub t1: Mat,
    pub t2: Mat,
    pub qr: QrScratch,
}

impl NodeScratch {
    pub fn new() -> NodeScratch {
        NodeScratch::default()
    }
}

/// Allocate one scratch per node (the runner-side workspace).
pub fn node_scratch(n: usize) -> Vec<NodeScratch> {
    let mut v = Vec::with_capacity(n);
    v.resize_with(n, NodeScratch::new);
    v
}

/// One matrix's raw write view, snapshotted while the unique
/// `&mut [Mat]` borrow is held (so `as_mut_ptr` is called with
/// exclusive access — never concurrently).
#[derive(Clone, Copy, Debug)]
struct MatView {
    ptr: *mut f64,
    rows: usize,
    cols: usize,
}

/// Reusable backing store for [`DisjointMatRows`]. Hot loops (one
/// consensus round per fill) keep one of these alive so refilling the
/// view table is allocation-free after warm-up (`clear` + `extend`
/// reuse capacity).
#[derive(Debug, Default)]
pub struct MatRowsScratch {
    views: Vec<MatView>,
}

// SAFETY: between dispatches the stored views are inert (never
// dereferenced until the next `fill` rebuilds them under a fresh unique
// borrow), so moving the scratch — and the workspaces/networks that own
// one — across threads is sound. Keeps `SyncNetwork: Send`.
unsafe impl Send for MatRowsScratch {}

impl MatRowsScratch {
    pub fn new() -> MatRowsScratch {
        MatRowsScratch::default()
    }

    /// Snapshot `mats` into a [`DisjointMatRows`]. The returned handle
    /// holds the `&mut [Mat]` borrow for its lifetime, so the shapes and
    /// buffers it captured cannot move or change while tasks write
    /// through it.
    pub fn fill<'a>(&'a mut self, mats: &'a mut [Mat]) -> DisjointMatRows<'a> {
        self.views.clear();
        self.views.extend(mats.iter_mut().map(|m| MatView {
            ptr: m.data.as_mut_ptr(),
            rows: m.rows,
            cols: m.cols,
        }));
        DisjointMatRows { views: &self.views, _marker: PhantomData }
    }
}

/// Shared view over a `&mut [Mat]` allowing concurrent writes to
/// **disjoint row ranges** of each matrix — the write-side primitive of
/// two-level dispatches ([`NodePool::run_chunks2`]). Built via
/// [`MatRowsScratch::fill`].
///
/// [`DisjointSlice`](crate::runtime::pool::DisjointSlice) hands out
/// `&mut Mat` per index, which is unsound when two row chunks of the
/// *same* matrix are in flight. This wrapper instead snapshots each
/// matrix's `(buffer pointer, rows, cols)` **up front, under the unique
/// borrow** — the concurrent path then carves disjoint `&mut [f64]` row
/// slices from the stored raw pointers without ever materializing a
/// reference to a `Mat` or its `Vec` header, so no aliasing references
/// exist between tasks.
///
/// [`NodePool::run_chunks2`]: crate::runtime::pool::NodePool::run_chunks2
pub struct DisjointMatRows<'a> {
    views: &'a [MatView],
    _marker: PhantomData<&'a mut [Mat]>,
}

// SAFETY: access is coordinated by the caller exactly as for
// `DisjointSlice` — each in-flight task touches only its own row range,
// through per-matrix pointers captured under the unique borrow.
unsafe impl Send for DisjointMatRows<'_> {}
// SAFETY: as above — `rows_mut` hands out non-overlapping ranges only
// under the caller's disjointness contract; shared refs do no writes.
unsafe impl Sync for DisjointMatRows<'_> {}

impl DisjointMatRows<'_> {
    pub fn len(&self) -> usize {
        self.views.len()
    }

    pub fn is_empty(&self) -> bool {
        self.views.is_empty()
    }

    /// Row count of matrix `i` (safe: shapes were snapshotted under the
    /// unique borrow and cannot change while this handle lives).
    pub fn rows(&self, i: usize) -> usize {
        self.views[i].rows
    }

    /// Mutable slice over rows `lo..hi` of matrix `i`.
    ///
    /// # Safety
    /// While the dispatch is in flight, no other task may access any row
    /// in `[lo, hi)` of matrix `i` (bounds are assert-checked).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn rows_mut(&self, i: usize, lo: usize, hi: usize) -> &mut [f64] {
        let v = self.views[i];
        assert!(lo <= hi && hi <= v.rows, "row range {lo}..{hi} out of bounds ({})", v.rows);
        // SAFETY: the range is in bounds (asserted above against the
        // snapshotted shape) and the fn contract makes this task the
        // only one touching rows [lo, hi) of matrix `i`, so the produced
        // slice is exclusive.
        unsafe { std::slice::from_raw_parts_mut(v.ptr.add(lo * v.cols), (hi - lo) * v.cols) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ensure_mats_tracks_shapes_and_reuses_capacity() {
        let mut ws = ConsensusWorkspace::new();
        let z: Vec<Mat> = (0..3).map(|_| Mat::zeros(10, 4)).collect();
        ws.ensure_mats(&z);
        assert_eq!(ws.next.len(), 3);
        assert_eq!((ws.next[0].rows, ws.next[0].cols), (10, 4));
        let cap_before = ws.next[0].data.capacity();
        // Shrink then grow back: capacity must be retained (no realloc).
        let small: Vec<Mat> = (0..3).map(|_| Mat::zeros(2, 2)).collect();
        ws.ensure_mats(&small);
        assert_eq!((ws.next[1].rows, ws.next[1].cols), (2, 2));
        ws.ensure_mats(&z);
        assert!(ws.next[0].data.capacity() >= cap_before);
    }

    #[test]
    fn ensure_scalars_resets_values() {
        let mut ws = ConsensusWorkspace::new();
        ws.ensure_scalars(4, 0.25);
        assert_eq!(ws.w_src, vec![0.25; 4]);
        ws.w_src[2] = 9.0;
        ws.ensure_scalars(4, 0.25);
        assert_eq!(ws.w_src, vec![0.25; 4]);
    }

    #[test]
    fn node_scratch_sized() {
        let s = node_scratch(5);
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn disjoint_mat_rows_carves_expected_slices() {
        let mut mats: Vec<Mat> = vec![Mat::zeros(4, 3), Mat::zeros(2, 5)];
        let mut scratch = MatRowsScratch::new();
        {
            let d = scratch.fill(&mut mats);
            assert_eq!(d.len(), 2);
            assert_eq!(d.rows(0), 4);
            // SAFETY: single-threaded, sequential disjoint accesses.
            unsafe {
                d.rows_mut(0, 1, 3).fill(7.0);
                d.rows_mut(1, 0, 2).fill(2.0);
            }
        }
        assert_eq!(mats[0].row(0), &[0.0, 0.0, 0.0]);
        assert_eq!(mats[0].row(1), &[7.0, 7.0, 7.0]);
        assert_eq!(mats[0].row(2), &[7.0, 7.0, 7.0]);
        assert_eq!(mats[0].row(3), &[0.0, 0.0, 0.0]);
        assert!(mats[1].data.iter().all(|&v| v == 2.0));
    }
}
