//! Step-12 orthonormalization executor: per-node policy dispatch plus
//! the TSQR **(node × leaf)** fan-out.
//!
//! Every per-node QR in this crate used to be sequential inside its node
//! chunk — the last serial stage of the outer iteration. For the
//! [`QrPolicy::Tsqr`] policy this module flattens the work one level
//! further: node `i`'s input is split into its fixed
//! [`tsqr_leaves`]`(d, r)` row blocks, and the `Σ_i L_i` leaf
//! factorizations fan across the pool as one task grid (then again for
//! the leaf-apply stage), so a d = 2914 QR uses every core even at
//! N < threads.
//!
//! # Determinism
//!
//! The leaf partition and the reduction tree are pure functions of each
//! matrix's shape (`tsqr_leaves` / `tsqr_leaf_bounds` — the same
//! `chunk_bounds` policy as `run_chunks2`), never of the thread count;
//! each leaf owns a private scratch; and the three phases run the
//! *identical* kernels as the serial `qr::tsqr_into`. So for any
//! `--threads` the output is bitwise the serial result — the same
//! contract as every other dispatch in [`crate::runtime::pool`]
//! (asserted by `tests/test_parallel_determinism.rs`).
//!
//! All fan-out buffers live in [`QrFanScratch`] and only grow, keeping
//! the steady-state outer iteration at zero heap allocations
//! (`bench_hotpath` / `bench_qr` counting allocators).

use crate::linalg::qr::{
    tsqr_apply_leaf, tsqr_factor_leaf, tsqr_leaf_bounds, tsqr_leaves, tsqr_reduce, QrPolicy,
    TsqrLeaf, TsqrTree,
};
use crate::linalg::Mat;
use crate::runtime::pool::{DisjointSlice, NodePool};
use crate::runtime::workspace::{node_scratch, MatRowsScratch, NodeScratch};
use crate::runtime::{Backend, NativeBackend};
use std::sync::Mutex;

/// Reusable flat (node × leaf) workspace for the TSQR fan-out: node
/// `i`'s leaves live at `leaves[i·lmax .. i·lmax + L_i]` (node-major),
/// its reduction tree at `trees[i]`. Buffers only grow, so after warm-up
/// the fan-out allocates nothing.
#[derive(Debug, Default)]
pub struct QrFanScratch {
    leaves: Vec<TsqrLeaf>,
    trees: Vec<TsqrTree>,
}

impl QrFanScratch {
    pub fn new() -> QrFanScratch {
        QrFanScratch::default()
    }

    fn ensure(&mut self, nodes: usize, lmax: usize) {
        if self.leaves.len() < nodes * lmax {
            self.leaves.resize_with(nodes * lmax, TsqrLeaf::default);
        }
        if self.trees.len() < nodes {
            self.trees.resize_with(nodes, TsqrTree::default);
        }
    }
}

/// Orthonormalize every `z[i]` into `q[i]` (Alg. 1 step 12) across the
/// pool, honoring the backend's [`QrPolicy`].
///
/// Householder/Blocked policies (and any non-row-split backend) keep the
/// node-level dispatch: one chunk per node, QR sequential within it. The
/// TSQR policy on a row-split backend with threads to spare switches to
/// the three-phase (node × leaf) fan-out described in the module docs.
pub fn orthonormalize_nodes(
    pool: &NodePool,
    backend: &dyn Backend,
    z: &[Mat],
    q: &mut [Mat],
    scratch: &mut [NodeScratch],
    fan: &mut QrFanScratch,
    views: &mut MatRowsScratch,
) {
    let n = z.len();
    assert_eq!(q.len(), n, "z/q node count mismatch");
    assert_eq!(scratch.len(), n, "z/scratch node count mismatch");
    let fanout = backend.qr_policy() == QrPolicy::Tsqr
        && backend.supports_row_split()
        && pool.threads() > 1
        && z.iter().any(|zi| tsqr_leaves(zi.rows, zi.cols) > 1);
    if !fanout {
        let qs = DisjointSlice::new(q);
        let scr = DisjointSlice::new(scratch);
        pool.run_chunks(n, &|lo, hi| {
            for i in lo..hi {
                // SAFETY: index i belongs to exactly one chunk.
                let (qi, si) = unsafe { (qs.get_mut(i), scr.get_mut(i)) };
                backend.orthonormalize_into(&z[i], qi, &mut si.qr);
            }
        });
        return;
    }

    let lmax = z.iter().map(|zi| tsqr_leaves(zi.rows, zi.cols)).max().unwrap_or(1);
    fan.ensure(n, lmax);
    for (qi, zi) in q.iter_mut().zip(z.iter()) {
        qi.reshape_in_place(zi.rows, zi.cols);
    }
    // Phase A: leaf factorizations over the flattened (node, leaf) grid.
    {
        let leaves = DisjointSlice::new(&mut fan.leaves);
        pool.run_chunks(n * lmax, &|lo, hi| {
            for t in lo..hi {
                let (i, c) = (t / lmax, t % lmax);
                let li = tsqr_leaves(z[i].rows, z[i].cols);
                if c >= li {
                    continue;
                }
                let (rlo, rhi) = tsqr_leaf_bounds(z[i].rows, li, c);
                // SAFETY: slot (i, c) belongs to exactly one task.
                let leaf = unsafe { leaves.get_mut(i * lmax + c) };
                tsqr_factor_leaf(&z[i], rlo, rhi, leaf);
            }
        });
    }
    // Phase B: per-node R-tree reduction + leaf coefficients (r×r work;
    // nodes with a single leaf have no tree).
    {
        let trees = DisjointSlice::new(&mut fan.trees);
        let leaves = &fan.leaves;
        pool.run_chunks(n, &|lo, hi| {
            for i in lo..hi {
                let li = tsqr_leaves(z[i].rows, z[i].cols);
                if li <= 1 {
                    continue;
                }
                // SAFETY: tree i belongs to exactly one chunk.
                let tree = unsafe { trees.get_mut(i) };
                tsqr_reduce(&leaves[i * lmax..i * lmax + li], tree, z[i].cols);
            }
        });
    }
    // Phase C: expand each leaf's slice of the final Q, again over the
    // (node, leaf) grid — disjoint row ranges of q[i].
    {
        let qviews = views.fill(q);
        let leaves = &fan.leaves;
        let trees = &fan.trees;
        pool.run_chunks(n * lmax, &|lo, hi| {
            for t in lo..hi {
                let (i, c) = (t / lmax, t % lmax);
                let li = tsqr_leaves(z[i].rows, z[i].cols);
                if c >= li {
                    continue;
                }
                let (rlo, rhi) = tsqr_leaf_bounds(z[i].rows, li, c);
                // SAFETY: rows [rlo, rhi) of q[i] belong to one task.
                let out = unsafe { qviews.rows_mut(i, rlo, rhi) };
                let leaf = &leaves[i * lmax + c];
                if li == 1 {
                    // Single leaf: the leaf factor *is* the thin Q —
                    // bitwise the serial `tsqr_into` delegation to the
                    // scalar kernel for this shape.
                    out.copy_from_slice(&leaf.q().data);
                } else {
                    tsqr_apply_leaf(leaf, trees[i].coeff(c), out);
                }
            }
        });
    }
}

/// Shared step-12 executor for SPMD node bodies (`network::mpi`): one
/// pool + backend + scratch set behind a mutex. SPMD node bodies run on
/// their own persistent workers, so step-12 calls serialize across
/// nodes, but each node's QR row-fans across the whole shared pool — so
/// MPI runs saturate cores on the orthonormalization exactly like the
/// simulator does. Because [`orthonormalize_nodes`] is bitwise the
/// serial kernel for every thread count, routing a node body through the
/// shared executor never changes its results.
pub struct SharedQr {
    inner: Mutex<SharedQrInner>,
}

struct SharedQrInner {
    pool: NodePool,
    backend: NativeBackend,
    q: Vec<Mat>,
    scratch: Vec<NodeScratch>,
    fan: QrFanScratch,
    views: MatRowsScratch,
}

impl SharedQr {
    /// An executor over `threads` pool threads, snapshotting the
    /// process-wide `--qr` policy (like `NativeBackend::default`).
    pub fn new(threads: usize) -> SharedQr {
        SharedQr {
            inner: Mutex::new(SharedQrInner {
                pool: NodePool::new(threads),
                backend: NativeBackend::default(),
                q: vec![Mat::zeros(0, 0)],
                scratch: node_scratch(1),
                fan: QrFanScratch::new(),
                views: MatRowsScratch::new(),
            }),
        }
    }

    /// Orthonormalize `z` into `out` (Alg. 1 step 12) on the shared
    /// pool. Scratch is reused across calls and callers, so the
    /// steady-state cost is the factorization itself.
    pub fn orthonormalize(&self, z: &Mat, out: &mut Mat) {
        let mut guard = self.inner.lock().expect("SharedQr lock");
        let inner = &mut *guard;
        orthonormalize_nodes(
            &inner.pool,
            &inner.backend,
            std::slice::from_ref(z),
            &mut inner.q,
            &mut inner.scratch,
            &mut inner.fan,
            &mut inner.views,
        );
        std::mem::swap(out, &mut inner.q[0]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::{tsqr_into, QrScratch};
    use crate::util::rng::Rng;

    fn fanout_inputs(seed: u64, shapes: &[(usize, usize)]) -> Vec<Mat> {
        let mut rng = Rng::new(seed);
        shapes.iter().map(|&(m, n)| Mat::gauss(m, n, &mut rng)).collect()
    }

    fn serial_reference(z: &[Mat]) -> Vec<Mat> {
        let mut ws = QrScratch::new();
        z.iter()
            .map(|zi| {
                let mut q = Mat::zeros(0, 0);
                tsqr_into(zi, &mut q, None, &mut ws);
                q
            })
            .collect()
    }

    /// The pooled fan-out must be bitwise the serial `tsqr_into`, for
    /// any thread count, leaf-count mix (incl. single-leaf nodes), and
    /// across repeated dispatches on reused scratch.
    #[test]
    fn fanout_bitwise_matches_serial_tsqr() {
        let z = fanout_inputs(1, &[(300, 4), (100, 4), (350, 3), (420, 5)]);
        let want = serial_reference(&z);
        let backend = NativeBackend::with_policy(QrPolicy::Tsqr);
        for &threads in &[2usize, 4, 9] {
            let pool = NodePool::new(threads);
            let mut q: Vec<Mat> = (0..z.len()).map(|_| Mat::zeros(0, 0)).collect();
            let mut scratch = node_scratch(z.len());
            let mut fan = QrFanScratch::new();
            let mut views = MatRowsScratch::new();
            for round in 0..3 {
                orthonormalize_nodes(
                    &pool, &backend, &z, &mut q, &mut scratch, &mut fan, &mut views,
                );
                for (i, (got, exp)) in q.iter().zip(want.iter()).enumerate() {
                    assert_eq!((got.rows, got.cols), (exp.rows, exp.cols));
                    assert_eq!(got.data, exp.data, "threads={threads} round={round} node={i}");
                }
            }
        }
    }

    /// threads = 1 (and non-TSQR policies) take the per-node path and
    /// must agree with the fan-out bitwise too.
    #[test]
    fn node_path_and_fanout_agree() {
        let z = fanout_inputs(2, &[(300, 4), (300, 4)]);
        let backend = NativeBackend::with_policy(QrPolicy::Tsqr);
        let run = |threads: usize| {
            let pool = NodePool::new(threads);
            let mut q: Vec<Mat> = (0..z.len()).map(|_| Mat::zeros(0, 0)).collect();
            let mut scratch = node_scratch(z.len());
            let mut fan = QrFanScratch::new();
            let mut views = MatRowsScratch::new();
            orthonormalize_nodes(&pool, &backend, &z, &mut q, &mut scratch, &mut fan, &mut views);
            q
        };
        let serial = run(1);
        let pooled = run(4);
        for (a, b) in serial.iter().zip(pooled.iter()) {
            assert_eq!(a.data, b.data);
        }
        // Householder policy through the same entry point: orthonormal
        // output via the node-level dispatch.
        let backend_h = NativeBackend::with_policy(QrPolicy::Householder);
        let pool = NodePool::new(4);
        let mut q: Vec<Mat> = (0..z.len()).map(|_| Mat::zeros(0, 0)).collect();
        let mut scratch = node_scratch(z.len());
        let mut fan = QrFanScratch::new();
        let mut views = MatRowsScratch::new();
        orthonormalize_nodes(&pool, &backend_h, &z, &mut q, &mut scratch, &mut fan, &mut views);
        for qi in &q {
            let g = qi.t_matmul(qi);
            assert!(g.dist_fro(&Mat::eye(qi.cols)) < 1e-10);
        }
    }

    #[test]
    fn shared_qr_matches_direct_backend_bitwise() {
        let z = fanout_inputs(3, &[(300, 4), (40, 3)]);
        let shared = SharedQr::new(4);
        let backend = NativeBackend::default();
        let mut scratch = node_scratch(1);
        for (round, zi) in z.iter().cycle().take(4).enumerate() {
            let mut got = Mat::zeros(0, 0);
            shared.orthonormalize(zi, &mut got);
            let mut want = Mat::zeros(0, 0);
            backend.orthonormalize_into(zi, &mut want, &mut scratch[0].qr);
            assert_eq!((got.rows, got.cols), (want.rows, want.cols));
            assert_eq!(got.data, want.data, "round {round}");
        }
    }
}
