//! Network topologies.
//!
//! The paper evaluates Erdős–Rényi, ring and star topologies (Section V);
//! we additionally provide path, complete and 2-D grid graphs for ablations.
//! All graphs are undirected and simple; generators reject disconnected
//! samples (the paper requires a connected network).

use crate::util::rng::Rng;

/// An undirected graph on nodes `0..n`, stored as sorted adjacency lists.
#[derive(Clone, Debug)]
pub struct Graph {
    pub n: usize,
    pub adj: Vec<Vec<usize>>,
    /// Human-readable topology tag ("erdos(p=0.25)", "ring", "star", …).
    pub kind: String,
}

impl Graph {
    fn from_edges(n: usize, edges: &[(usize, usize)], kind: String) -> Graph {
        let mut adj = vec![Vec::new(); n];
        for &(i, j) in edges {
            assert!(i != j && i < n && j < n, "bad edge ({i},{j})");
            adj[i].push(j);
            adj[j].push(i);
        }
        for a in adj.iter_mut() {
            a.sort_unstable();
            a.dedup();
        }
        Graph { n, adj, kind }
    }

    /// Node count up to which [`Graph::erdos_renyi`] keeps the
    /// historical pair-by-pair sampler, so every seed-pinned small-graph
    /// sample in tests and experiments is bit-for-bit unchanged; above
    /// it edges are drawn by geometric skipping in O(edges).
    pub const ER_DENSE_SAMPLER_MAX: usize = 64;

    /// Erdős–Rényi G(n, p), resampled until connected.
    ///
    /// Sampling is O(n²) per attempt only up to
    /// [`Graph::ER_DENSE_SAMPLER_MAX`] nodes (RNG-stream compatibility
    /// for paper-sized graphs); larger graphs use geometric skipping
    /// over the linearized upper triangle (Batagelj–Brandes), one draw
    /// per realized edge — the path that makes N = 10⁴ sweeps feasible.
    ///
    /// Panics after 10_000 failed connectivity resamples, reporting the
    /// G(n, p) connectivity threshold `ln(n)/n` so the caller knows how
    /// far below it the requested `p` sits.
    pub fn erdos_renyi(n: usize, p: f64, rng: &mut Rng) -> Graph {
        assert!(n >= 2);
        assert!((0.0..=1.0).contains(&p), "erdos_renyi: p={p} must lie in [0, 1]");
        const ATTEMPTS: usize = 10_000;
        for _attempt in 0..ATTEMPTS {
            let g = if n <= Graph::ER_DENSE_SAMPLER_MAX {
                Graph::er_sample_dense(n, p, rng)
            } else {
                Graph::er_sample_skip(n, p, rng)
            };
            if g.is_connected() {
                return g;
            }
        }
        let threshold = (n as f64).ln() / n as f64;
        panic!(
            "erdos_renyi(n={n}, p={p}): no connected sample in {ATTEMPTS} attempts — \
             G(n, p) is connected w.h.p. only for p \u{2273} ln(n)/n = {threshold:.4}; \
             raise p toward or above that threshold (or pick a deterministic topology)"
        );
    }

    /// Historical O(n²) pair-by-pair G(n, p) sampler.
    fn er_sample_dense(n: usize, p: f64, rng: &mut Rng) -> Graph {
        let mut edges = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                if rng.bernoulli(p) {
                    edges.push((i, j));
                }
            }
        }
        Graph::from_edges(n, &edges, format!("erdos(p={p})"))
    }

    /// Geometric-skipping G(n, p) sampler (Batagelj–Brandes): walk the
    /// linearized upper triangle jumping a Geometric(p) gap per edge, so
    /// one attempt costs O(n + edges) draws instead of n(n−1)/2.
    fn er_sample_skip(n: usize, p: f64, rng: &mut Rng) -> Graph {
        let mut edges = Vec::new();
        if p >= 1.0 {
            for i in 0..n {
                for j in (i + 1)..n {
                    edges.push((i, j));
                }
            }
        } else if p > 0.0 {
            let lq = (1.0 - p).ln();
            let mut v = 1usize;
            let mut w = -1i64;
            while v < n {
                let r = rng.next_f64();
                let skip = ((1.0 - r).ln() / lq).floor();
                if !skip.is_finite() || skip >= (n * n) as f64 {
                    break; // jumped past every remaining pair
                }
                w += 1 + skip as i64;
                while v < n && w >= v as i64 {
                    w -= v as i64;
                    v += 1;
                }
                if v < n {
                    edges.push((w as usize, v));
                }
            }
        }
        Graph::from_edges(n, &edges, format!("erdos(p={p})"))
    }

    /// Ring: node i ↔ (i+1) mod n.
    pub fn ring(n: usize) -> Graph {
        assert!(n >= 3);
        let edges: Vec<_> = (0..n).map(|i| (i, (i + 1) % n)).collect();
        Graph::from_edges(n, &edges, "ring".into())
    }

    /// Star: node 0 is the hub.
    pub fn star(n: usize) -> Graph {
        assert!(n >= 2);
        let edges: Vec<_> = (1..n).map(|i| (0, i)).collect();
        Graph::from_edges(n, &edges, "star".into())
    }

    /// Path: 0 – 1 – … – (n-1).
    pub fn path(n: usize) -> Graph {
        assert!(n >= 2);
        let edges: Vec<_> = (0..n - 1).map(|i| (i, i + 1)).collect();
        Graph::from_edges(n, &edges, "path".into())
    }

    /// A single isolated node (consensus over it is the identity and
    /// sends no messages) — the degenerate group of B-DOT's R=1 / C=1
    /// grids.
    pub fn single() -> Graph {
        Graph { n: 1, adj: vec![Vec::new()], kind: "single".into() }
    }

    /// Complete graph.
    pub fn complete(n: usize) -> Graph {
        let mut edges = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                edges.push((i, j));
            }
        }
        Graph::from_edges(n, &edges, "complete".into())
    }

    /// `rows × cols` 2-D grid.
    pub fn grid(rows: usize, cols: usize) -> Graph {
        let n = rows * cols;
        let mut edges = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                let v = r * cols + c;
                if c + 1 < cols {
                    edges.push((v, v + 1));
                }
                if r + 1 < rows {
                    edges.push((v, v + cols));
                }
            }
        }
        Graph::from_edges(n, &edges, format!("grid({rows}x{cols})"))
    }

    /// Parse a topology spec: "erdos" (needs p), "ring", "star", "path",
    /// "complete", "grid" (near-square mesh over n nodes; a perfect
    /// square n keeps the exact √n × √n grid).
    pub fn from_spec(spec: &str, n: usize, p: f64, rng: &mut Rng) -> Graph {
        match spec {
            "erdos" | "er" => Graph::erdos_renyi(n, p, rng),
            "ring" => Graph::ring(n),
            "star" => Graph::star(n),
            "path" => Graph::path(n),
            "complete" => Graph::complete(n),
            "grid" => {
                let (r, c) = near_square(n);
                Graph::grid(r, c)
            }
            other => panic!("unknown topology '{other}'"),
        }
    }

    pub fn degree(&self, i: usize) -> usize {
        self.adj[i].len()
    }

    pub fn max_degree(&self) -> usize {
        self.adj.iter().map(|a| a.len()).max().unwrap_or(0)
    }

    pub fn avg_degree(&self) -> f64 {
        self.adj.iter().map(|a| a.len()).sum::<usize>() as f64 / self.n as f64
    }

    pub fn edge_count(&self) -> usize {
        self.adj.iter().map(|a| a.len()).sum::<usize>() / 2
    }

    /// Neighbors *including self* — the `N_i` of the paper.
    pub fn closed_neighborhood(&self, i: usize) -> Vec<usize> {
        let mut v = self.adj[i].clone();
        v.push(i);
        v.sort_unstable();
        v
    }

    /// BFS connectivity check.
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut queue = std::collections::VecDeque::new();
        queue.push_back(0);
        seen[0] = true;
        let mut count = 1;
        while let Some(v) = queue.pop_front() {
            for &w in &self.adj[v] {
                if !seen[w] {
                    seen[w] = true;
                    count += 1;
                    queue.push_back(w);
                }
            }
        }
        count == self.n
    }

    /// BFS connectivity of the subgraph induced by `alive` nodes — the
    /// surviving network after fault-plan churn. Vacuously true when no
    /// node (or a single node) survives.
    pub fn is_connected_over(&self, alive: &[bool]) -> bool {
        assert_eq!(alive.len(), self.n);
        let Some(start) = (0..self.n).find(|&i| alive[i]) else {
            return true;
        };
        let total = alive.iter().filter(|&&a| a).count();
        let mut seen = vec![false; self.n];
        let mut queue = std::collections::VecDeque::new();
        queue.push_back(start);
        seen[start] = true;
        let mut count = 1;
        while let Some(v) = queue.pop_front() {
            for &w in &self.adj[v] {
                if alive[w] && !seen[w] {
                    seen[w] = true;
                    count += 1;
                    queue.push_back(w);
                }
            }
        }
        count == total
    }

    /// Graph diameter (max BFS eccentricity); O(n·m), fine for n ≤ few hundred.
    pub fn diameter(&self) -> usize {
        let mut diam = 0;
        for s in 0..self.n {
            let mut dist = vec![usize::MAX; self.n];
            let mut queue = std::collections::VecDeque::new();
            dist[s] = 0;
            queue.push_back(s);
            while let Some(v) = queue.pop_front() {
                for &w in &self.adj[v] {
                    if dist[w] == usize::MAX {
                        dist[w] = dist[v] + 1;
                        queue.push_back(w);
                    }
                }
            }
            diam = diam.max(*dist.iter().max().unwrap());
        }
        diam
    }
}

/// Topology family for a consensus group of parameterized size — wires
/// real (non-complete) group networks through B-DOT's row / column / grid
/// phases and the topology ablations without hard-coding node counts.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GroupTopo {
    Complete,
    Ring,
    Star,
    Path,
    /// 2-D mesh: [`GroupTopo::build`] uses the near-square factorization
    /// of `n`; [`GroupTopo::build_rect`] uses the exact `R × C` mesh.
    Grid,
    /// Erdős–Rényi with the given edge probability (resampled until
    /// connected, deterministic in the seed).
    Erdos(f64),
}

impl GroupTopo {
    /// Build this topology on exactly `n` nodes. Degenerate sizes degrade
    /// to the only connected simple graphs — `n == 1` a single node (no
    /// edges, no messages), `n == 2` one edge — instead of padding with
    /// phantom nodes.
    pub fn build(&self, n: usize, seed: u64) -> Graph {
        assert!(n >= 1, "group must have at least one node");
        if n == 1 {
            return Graph::single();
        }
        if n == 2 {
            return Graph::path(2);
        }
        match *self {
            GroupTopo::Complete => Graph::complete(n),
            GroupTopo::Ring => Graph::ring(n),
            GroupTopo::Star => Graph::star(n),
            GroupTopo::Path => Graph::path(n),
            GroupTopo::Grid => {
                let (r, c) = near_square(n);
                Graph::grid(r, c)
            }
            GroupTopo::Erdos(p) => {
                let mut rng = Rng::new(seed);
                Graph::erdos_renyi(n, p, &mut rng)
            }
        }
    }

    /// Build over an `rows × cols` grid of members. `Grid` uses the exact
    /// mesh (so B-DOT's whole-grid network is the literal node grid);
    /// every other family sees `rows · cols` interchangeable members.
    pub fn build_rect(&self, rows: usize, cols: usize, seed: u64) -> Graph {
        match *self {
            GroupTopo::Grid => Graph::grid(rows, cols),
            _ => self.build(rows * cols, seed),
        }
    }
}

/// Factor pair `(r, c)` of `n` with `r ≤ c` and `r` as close to `√n` as
/// divisibility allows (primes fall back to a `1 × n` path-like mesh).
fn near_square(n: usize) -> (usize, usize) {
    let mut r = ((n as f64).sqrt().floor() as usize).max(1);
    while r > 1 && n % r != 0 {
        r -= 1;
    }
    (r, n / r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_structure() {
        let g = Graph::ring(6);
        assert_eq!(g.edge_count(), 6);
        for i in 0..6 {
            assert_eq!(g.degree(i), 2);
        }
        assert!(g.is_connected());
        assert_eq!(g.diameter(), 3);
    }

    #[test]
    fn star_structure() {
        let g = Graph::star(20);
        assert_eq!(g.degree(0), 19);
        for i in 1..20 {
            assert_eq!(g.degree(i), 1);
        }
        assert_eq!(g.edge_count(), 19);
        assert_eq!(g.diameter(), 2);
    }

    #[test]
    fn path_and_complete() {
        let p = Graph::path(5);
        assert_eq!(p.edge_count(), 4);
        assert_eq!(p.diameter(), 4);
        let k = Graph::complete(7);
        assert_eq!(k.edge_count(), 21);
        assert_eq!(k.diameter(), 1);
    }

    #[test]
    fn grid_structure() {
        let g = Graph::grid(3, 4);
        assert_eq!(g.n, 12);
        assert_eq!(g.edge_count(), 3 * 3 + 2 * 4); // horizontal + vertical
        assert!(g.is_connected());
        // corner degree 2, center degree 4
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.degree(5), 4);
    }

    #[test]
    fn erdos_connected_and_plausible_degree() {
        let mut rng = Rng::new(1);
        let g = Graph::erdos_renyi(20, 0.25, &mut rng);
        assert!(g.is_connected());
        // E[deg] = p(n-1) = 4.75; realized average within generous bounds.
        let avg = g.avg_degree();
        assert!(avg > 2.0 && avg < 9.0, "avg={avg}");
    }

    #[test]
    fn erdos_deterministic_per_seed() {
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        let g1 = Graph::erdos_renyi(15, 0.3, &mut a);
        let g2 = Graph::erdos_renyi(15, 0.3, &mut b);
        assert_eq!(g1.adj, g2.adj);
    }

    #[test]
    fn closed_neighborhood_includes_self() {
        let g = Graph::star(5);
        let n0 = g.closed_neighborhood(0);
        assert_eq!(n0, vec![0, 1, 2, 3, 4]);
        let n3 = g.closed_neighborhood(3);
        assert_eq!(n3, vec![0, 3]);
    }

    #[test]
    fn from_spec_dispatch() {
        let mut rng = Rng::new(2);
        assert_eq!(Graph::from_spec("ring", 8, 0.0, &mut rng).kind, "ring");
        assert_eq!(Graph::from_spec("star", 8, 0.0, &mut rng).kind, "star");
        assert_eq!(Graph::from_spec("grid", 9, 0.0, &mut rng).n, 9);
        assert!(Graph::from_spec("erdos", 10, 0.5, &mut rng).is_connected());
    }

    #[test]
    fn from_spec_grid_accepts_non_square_counts() {
        let mut rng = Rng::new(2);
        // near_square(12) = (3, 4): same mesh GroupTopo::Grid builds.
        let g = Graph::from_spec("grid", 12, 0.0, &mut rng);
        assert_eq!(g.n, 12);
        assert_eq!(g.adj, GroupTopo::Grid.build(12, 0).adj);
        // Perfect squares keep the exact √n × √n grid.
        let sq = Graph::from_spec("grid", 16, 0.0, &mut rng);
        assert_eq!(sq.adj, Graph::grid(4, 4).adj);
    }

    #[test]
    fn erdos_large_n_geometric_sampler_is_deterministic_and_plausible() {
        let n = 300;
        let p = 2.0 * (n as f64).ln() / n as f64;
        let g1 = Graph::erdos_renyi(n, p, &mut Rng::new(42));
        let g2 = Graph::erdos_renyi(n, p, &mut Rng::new(42));
        assert_eq!(g1.adj, g2.adj);
        assert!(g1.is_connected());
        // E[deg] = p(n-1) ≈ 11.4; the sample mean over 300 nodes is tight.
        let avg = 2.0 * g1.edge_count() as f64 / n as f64;
        assert!(avg > 7.0 && avg < 16.0, "avg degree {avg}");
    }

    #[test]
    fn erdos_samplers_agree_on_density_across_the_gate() {
        // The two samplers draw different RNG streams, so graphs differ,
        // but edge densities must agree statistically at the same (n, p).
        let n = Graph::ER_DENSE_SAMPLER_MAX; // dense path
        let m = n + 1; // skip path
        let p = 0.25;
        let dense = Graph::erdos_renyi(n, p, &mut Rng::new(5));
        let skip = Graph::erdos_renyi(m, p, &mut Rng::new(5));
        let d_dense = 2.0 * dense.edge_count() as f64 / (n * (n - 1)) as f64;
        let d_skip = 2.0 * skip.edge_count() as f64 / (m * (m - 1)) as f64;
        assert!((d_dense - p).abs() < 0.08, "dense density {d_dense}");
        assert!((d_skip - p).abs() < 0.08, "skip density {d_skip}");
    }

    #[test]
    #[should_panic(expected = "ln(n)/n")]
    fn erdos_connectivity_failure_reports_threshold() {
        // p far below ln(n)/n: nearly empty samples, never connected.
        Graph::erdos_renyi(70, 0.001, &mut Rng::new(1));
    }

    #[test]
    #[should_panic]
    fn from_spec_unknown_panics() {
        let mut rng = Rng::new(3);
        Graph::from_spec("torus", 8, 0.0, &mut rng);
    }

    #[test]
    fn group_topo_degenerate_sizes() {
        for topo in [
            GroupTopo::Complete,
            GroupTopo::Ring,
            GroupTopo::Star,
            GroupTopo::Path,
            GroupTopo::Grid,
            GroupTopo::Erdos(0.5),
        ] {
            let g1 = topo.build(1, 7);
            assert_eq!(g1.n, 1);
            assert_eq!(g1.edge_count(), 0);
            assert!(g1.is_connected());
            let g2 = topo.build(2, 7);
            assert_eq!(g2.n, 2);
            assert_eq!(g2.edge_count(), 1);
        }
    }

    #[test]
    fn group_topo_builds_the_named_family() {
        assert_eq!(GroupTopo::Ring.build(6, 0).edge_count(), 6);
        assert_eq!(GroupTopo::Star.build(6, 0).degree(0), 5);
        assert_eq!(GroupTopo::Path.build(6, 0).diameter(), 5);
        assert_eq!(GroupTopo::Complete.build(6, 0).edge_count(), 15);
        // 12 → 3×4 mesh; 7 is prime → 1×7 path-like mesh.
        assert_eq!(GroupTopo::Grid.build(12, 0).edge_count(), 3 * 3 + 2 * 4);
        assert_eq!(GroupTopo::Grid.build(7, 0).edge_count(), 6);
        let e = GroupTopo::Erdos(0.6).build(8, 3);
        assert!(e.is_connected());
        // Same seed → same sample.
        assert_eq!(e.adj, GroupTopo::Erdos(0.6).build(8, 3).adj);
    }

    #[test]
    fn group_topo_build_rect_uses_exact_mesh() {
        let g = GroupTopo::Grid.build_rect(2, 4, 0);
        assert_eq!(g.n, 8);
        assert_eq!(g.edge_count(), 2 * 3 + 4); // horizontal + vertical
        // Non-grid families see rows·cols interchangeable members.
        assert_eq!(GroupTopo::Ring.build_rect(2, 3, 0).edge_count(), 6);
        assert_eq!(GroupTopo::Grid.build_rect(1, 1, 0).n, 1);
    }

    #[test]
    fn single_node_graph() {
        let g = Graph::single();
        assert_eq!(g.n, 1);
        assert!(g.is_connected());
        assert_eq!(g.diameter(), 0);
    }

    #[test]
    fn disconnected_detection() {
        // Build a graph manually with an isolated node via from_edges.
        let g = Graph::from_edges(3, &[(0, 1)], "manual".into());
        assert!(!g.is_connected());
    }
}
