//! Consensus-iteration schedules.
//!
//! S-DOT uses a fixed `T_c` per outer iteration; SA-DOT grows the budget
//! with the outer iteration index `t` (1-based), e.g. `⌈0.5t⌉+1`, `t+1`,
//! `2t+1` — optionally capped (`min(5t+1, 200)` in Table II). Matching the
//! paper's MPI implementation, adaptive schedules are additionally capped
//! at the fixed baseline budget when one is given.

use std::fmt;

/// Number of consensus rounds to run in outer iteration `t` (t = 1, 2, …).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Schedule {
    /// S-DOT: constant `T_c`.
    Fixed(usize),
    /// SA-DOT: `min(⌊slope·t⌋ + offset, cap)`.
    Adaptive { slope: f64, offset: usize, cap: usize },
}

impl Schedule {
    /// Convenience constructors mirroring the paper's notation.
    pub fn fixed(tc: usize) -> Schedule {
        Schedule::Fixed(tc)
    }

    /// `min(⌊slope·t⌋ + offset, cap)`.
    pub fn adaptive(slope: f64, offset: usize, cap: usize) -> Schedule {
        Schedule::Adaptive { slope, offset, cap }
    }

    /// Parse the paper's table notation: "50", "t+1", "2t+1", "0.5t+1",
    /// "min(5t+1,200)".
    pub fn parse(s: &str) -> Option<Schedule> {
        let s = s.trim().replace(' ', "");
        if let Ok(v) = s.parse::<usize>() {
            return Some(Schedule::Fixed(v));
        }
        let (body, cap) = if let Some(rest) = s.strip_prefix("min(") {
            let inner = rest.strip_suffix(')')?;
            let (body, cap) = inner.rsplit_once(',')?;
            (body.to_string(), cap.parse::<usize>().ok()?)
        } else {
            (s.clone(), usize::MAX)
        };
        // body looks like "<slope>t+<offset>" or "t+<offset>" or "t".
        let (slope_str, rest) = body.split_once('t')?;
        let slope: f64 = if slope_str.is_empty() { 1.0 } else { slope_str.parse().ok()? };
        let offset: usize = if rest.is_empty() {
            0
        } else {
            rest.strip_prefix('+')?.parse().ok()?
        };
        Some(Schedule::Adaptive { slope, offset, cap })
    }

    /// Rounds in outer iteration `t` (1-based).
    pub fn rounds_at(&self, t: usize) -> usize {
        match *self {
            Schedule::Fixed(tc) => tc,
            Schedule::Adaptive { slope, offset, cap } => {
                (((slope * t as f64).floor() as usize) + offset).min(cap)
            }
        }
    }

    /// Total consensus rounds over `t_o` outer iterations.
    pub fn total_rounds(&self, t_o: usize) -> usize {
        (1..=t_o).map(|t| self.rounds_at(t)).sum()
    }

    /// Cap an adaptive schedule to `cap` (used to align SA-DOT with the
    /// S-DOT baseline budget, as in Tables I–IV).
    pub fn with_cap(&self, new_cap: usize) -> Schedule {
        match *self {
            Schedule::Fixed(tc) => Schedule::Fixed(tc.min(new_cap)),
            Schedule::Adaptive { slope, offset, cap } => Schedule::Adaptive {
                slope,
                offset,
                cap: cap.min(new_cap),
            },
        }
    }
}

impl fmt::Display for Schedule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            Schedule::Fixed(tc) => write!(f, "{tc}"),
            Schedule::Adaptive { slope, offset, cap } => {
                let body = if (slope - 1.0).abs() < 1e-12 {
                    format!("t+{offset}")
                } else {
                    format!("{slope}t+{offset}")
                };
                if cap == usize::MAX {
                    write!(f, "{body}")
                } else {
                    write!(f, "min({body},{cap})")
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_schedule() {
        let s = Schedule::fixed(50);
        assert_eq!(s.rounds_at(1), 50);
        assert_eq!(s.rounds_at(200), 50);
        assert_eq!(s.total_rounds(200), 10_000);
    }

    #[test]
    fn adaptive_2t_plus_1_capped_50() {
        let s = Schedule::adaptive(2.0, 1, 50);
        assert_eq!(s.rounds_at(1), 3);
        assert_eq!(s.rounds_at(24), 49);
        assert_eq!(s.rounds_at(25), 50);
        assert_eq!(s.rounds_at(100), 50);
        // Matches the Table-I budget ratio check: total/10_000 ≈ 0.94
        let total = s.total_rounds(200);
        assert!(total > 9_300 && total < 9_500, "total={total}");
    }

    #[test]
    fn adaptive_half_t() {
        let s = Schedule::adaptive(0.5, 1, 50);
        assert_eq!(s.rounds_at(1), 1); // floor(0.5)+1
        assert_eq!(s.rounds_at(2), 2);
        assert_eq!(s.rounds_at(98), 50);
        let total = s.total_rounds(200);
        assert!(total > 7_400 && total < 7_800, "total={total}");
    }

    #[test]
    fn parse_notations() {
        assert_eq!(Schedule::parse("50"), Some(Schedule::Fixed(50)));
        assert_eq!(
            Schedule::parse("t+1"),
            Some(Schedule::Adaptive { slope: 1.0, offset: 1, cap: usize::MAX })
        );
        assert_eq!(
            Schedule::parse("2t+1"),
            Some(Schedule::Adaptive { slope: 2.0, offset: 1, cap: usize::MAX })
        );
        assert_eq!(
            Schedule::parse("0.5t+1"),
            Some(Schedule::Adaptive { slope: 0.5, offset: 1, cap: usize::MAX })
        );
        assert_eq!(
            Schedule::parse("min(5t+1,200)"),
            Some(Schedule::Adaptive { slope: 5.0, offset: 1, cap: 200 })
        );
        assert_eq!(Schedule::parse("garbage"), None);
    }

    #[test]
    fn with_cap_applies() {
        let s = Schedule::parse("2t+1").unwrap().with_cap(50);
        assert_eq!(s.rounds_at(1000), 50);
    }

    #[test]
    fn display_roundtrip() {
        for txt in ["50", "t+1", "2t+1", "min(5t+1,200)"] {
            let s = Schedule::parse(txt).unwrap();
            let shown = s.to_string();
            assert_eq!(Schedule::parse(&shown), Some(s), "{txt} -> {shown}");
        }
    }
}
