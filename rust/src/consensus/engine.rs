//! The consensus-averaging inner loop (Alg. 1 steps 6–11).
//!
//! Operates on one matrix per node and mixes them through the weight
//! matrix using **only graph-neighbor state** — the simulator enforces the
//! communication structure the algorithm would have on a real network, and
//! every neighbor exchange increments the P2P counters.

use super::weights::{active_local_degree_weights, WeightMatrix};
use crate::fault::FaultPlan;
use crate::graph::Graph;
use crate::linalg::Mat;
use crate::network::counters::P2pCounters;
use crate::runtime::pool::{DisjointSlice, NodePool};
use crate::runtime::workspace::MatRowsScratch;

/// Result of a consensus run.
#[derive(Clone, Debug)]
pub struct ConsensusOutcome {
    pub rounds: usize,
}

/// Rows `lo..hi` of one node's synchronous mixing update:
/// `dst ← w_ii src_i + Σ_{j∈adj(i)} w_ij src_j`.
///
/// Per-element operation order (copy, scale by `w_ii`, then one axpy per
/// neighbor in adjacency order) matches the historical whole-matrix
/// update exactly, so any row split assembles to the serial result
/// bitwise — the property that lets large-d mixing fan across leftover
/// threads when N < threads.
#[inline]
fn mix_node_rows(
    g: &Graph,
    wm: &WeightMatrix,
    src: &[Mat],
    i: usize,
    lo: usize,
    hi: usize,
    dst_rows: &mut [f64],
) {
    let cols = src[i].cols;
    let (s0, s1) = (lo * cols, hi * cols);
    let wii = wm.w.get(i, i);
    dst_rows.copy_from_slice(&src[i].data[s0..s1]);
    for v in dst_rows.iter_mut() {
        *v *= wii;
    }
    for &j in &g.adj[i] {
        let w = wm.w.get(i, j);
        for (d, &s) in dst_rows.iter_mut().zip(src[j].data[s0..s1].iter()) {
            *d += w * s;
        }
    }
}

/// The matching update for the push-sum scalar weight channel.
#[inline]
fn mix_scalar(g: &Graph, wm: &WeightMatrix, src: &[f64], i: usize) -> f64 {
    let mut s = wm.w.get(i, i) * src[i];
    for &j in &g.adj[i] {
        s += wm.w.get(i, j) * src[j];
    }
    s
}

/// The shared mixing engine: `rounds` synchronous consensus iterations
/// over a caller-provided double buffer, optionally carrying the
/// push-sum scalar weight channel in the same message (ratio consensus).
///
/// This is the single mixing kernel behind both [`average_consensus`]
/// and `SyncNetwork::ratio_consensus_sum` — mixing within a round fans
/// out across `pool` hierarchically (node chunks first, then rows of
/// each node's matrix when threads are left over — bitwise deterministic
/// for any thread count; see `runtime::pool`), and P2P accounting lives
/// in one place: each round node `i` sends `deg(i)` messages of
/// `rows·cols` elements, `+1` when the scalar channel rides along.
#[allow(clippy::too_many_arguments)]
pub fn consensus_rounds(
    g: &Graph,
    wm: &WeightMatrix,
    z: &mut Vec<Mat>,
    next: &mut Vec<Mat>,
    mut scalar: Option<(&mut Vec<f64>, &mut Vec<f64>)>,
    rounds: usize,
    counters: &mut P2pCounters,
    pool: &NodePool,
    views: &mut MatRowsScratch,
) -> ConsensusOutcome {
    let n = g.n;
    assert_eq!(z.len(), n);
    assert_eq!(next.len(), n);
    assert_eq!(wm.n(), n);
    if n == 0 || rounds == 0 {
        return ConsensusOutcome { rounds: 0 };
    }
    let elems = z[0].rows * z[0].cols + usize::from(scalar.is_some());
    let mat_rows = z[0].rows;
    for _round in 0..rounds {
        {
            let src: &[Mat] = z.as_slice();
            let dst = views.fill(next.as_mut_slice());
            match &mut scalar {
                Some((w_src, w_dst)) => {
                    let ws: &[f64] = w_src.as_slice();
                    let wd = DisjointSlice::new(w_dst.as_mut_slice());
                    pool.run_chunks2(n, &|_| mat_rows, &|i, lo, hi| {
                        // SAFETY: rows [lo, hi) of node i belong to
                        // exactly one task; the scalar slot is written
                        // only by the task owning the first rows.
                        let d = unsafe { dst.rows_mut(i, lo, hi) };
                        mix_node_rows(g, wm, src, i, lo, hi, d);
                        if lo == 0 {
                            // SAFETY: slot i is written only by the task
                            // owning the first rows of node i.
                            unsafe { *wd.get_mut(i) = mix_scalar(g, wm, ws, i) };
                        }
                    });
                }
                None => {
                    pool.run_chunks2(n, &|_| mat_rows, &|i, lo, hi| {
                        // SAFETY: rows [lo, hi) of node i belong to
                        // exactly one task.
                        let d = unsafe { dst.rows_mut(i, lo, hi) };
                        mix_node_rows(g, wm, src, i, lo, hi, d);
                    });
                }
            }
        }
        for i in 0..n {
            // i sends one matrix to each neighbor (the read of z[j] above
            // is the receive side of j's send).
            counters.record_sends(i, g.degree(i) as u64, elems);
        }
        std::mem::swap(z, next);
        if let Some((w_src, w_dst)) = &mut scalar {
            std::mem::swap(*w_src, *w_dst);
        }
    }
    ConsensusOutcome { rounds }
}

/// Rows `lo..hi` of one node's mixing update under an active
/// [`FaultPlan`]: a dead node freezes (`dst ← src_i`); an alive node
/// mixes with the **active-subgraph** weights, substituting its own
/// value for any neighbor message severed by a partition or dropped by
/// the loss coin (`dst += w_ij src_i` instead of `w_ij src_j`). The
/// self-substitution keeps every realized row stochastic, so iterates
/// stay bounded under arbitrary loss. All fault verdicts are pure
/// functions of `(plan, round, i, j)`, so any row split still assembles
/// to the serial result bitwise.
#[allow(clippy::too_many_arguments)]
#[inline]
fn mix_node_rows_faulty(
    g: &Graph,
    awm: &WeightMatrix,
    plan: &FaultPlan,
    round: u64,
    alive: &[bool],
    src: &[Mat],
    i: usize,
    lo: usize,
    hi: usize,
    dst_rows: &mut [f64],
) {
    let cols = src[i].cols;
    let (s0, s1) = (lo * cols, hi * cols);
    dst_rows.copy_from_slice(&src[i].data[s0..s1]);
    if !alive[i] {
        return;
    }
    let wii = awm.w.get(i, i);
    for v in dst_rows.iter_mut() {
        *v *= wii;
    }
    for &j in &g.adj[i] {
        if !alive[j] {
            continue; // w_ij is 0 in the active weights
        }
        let w = awm.w.get(i, j);
        let from = if plan.edge_cut(round, i, j) || plan.msg_lost(round, j, i) {
            i // message j → i did not arrive: fold w_ij onto own value
        } else {
            j
        };
        for (d, &s) in dst_rows.iter_mut().zip(src[from].data[s0..s1].iter()) {
            *d += w * s;
        }
    }
}

/// The matching faulty update for the push-sum scalar channel.
#[inline]
fn mix_scalar_faulty(
    g: &Graph,
    awm: &WeightMatrix,
    plan: &FaultPlan,
    round: u64,
    alive: &[bool],
    src: &[f64],
    i: usize,
) -> f64 {
    if !alive[i] {
        return src[i];
    }
    let mut s = awm.w.get(i, i) * src[i];
    for &j in &g.adj[i] {
        if !alive[j] {
            continue;
        }
        let w = awm.w.get(i, j);
        let from =
            if plan.edge_cut(round, i, j) || plan.msg_lost(round, j, i) { i } else { j };
        s += w * src[from];
    }
    s
}

/// The fault-tolerant sibling of [`consensus_rounds`]: `rounds`
/// synchronous iterations under a [`FaultPlan`], starting at the global
/// consensus-round stamp `start_round` (the simulator's virtual clock).
///
/// Membership is re-evaluated every round and the Metropolis–Hastings
/// weights are re-normalized on the surviving subgraph at every
/// membership epoch (graceful degradation instead of a panic). The
/// optional `scalar` channel rides along under **identical** fault
/// verdicts — `SyncNetwork::consensus_sum` seeds it with `e₁` so the
/// Alg. 1 step-11 rescale tracks the *realized* time-varying mixing
/// product rather than a fixed `W^{T_c}`.
///
/// Counters: an alive node sends to each alive, non-partitioned
/// neighbor; a message eaten by the loss coin still counts (it was
/// transmitted), while a severed link or dead endpoint sends nothing.
/// This path may allocate (weights re-normalization at epochs) — the
/// zero-allocation contract covers only the fault-free path, which is
/// untouched.
///
/// Returns the advanced round stamp (`start_round + rounds`).
#[allow(clippy::too_many_arguments)]
pub fn faulty_consensus_rounds(
    g: &Graph,
    plan: &FaultPlan,
    start_round: u64,
    alive: &mut [bool],
    awm: &mut WeightMatrix,
    z: &mut Vec<Mat>,
    next: &mut Vec<Mat>,
    mut scalar: Option<(&mut Vec<f64>, &mut Vec<f64>)>,
    rounds: usize,
    counters: &mut P2pCounters,
    pool: &NodePool,
    views: &mut MatRowsScratch,
) -> u64 {
    let n = g.n;
    assert_eq!(z.len(), n);
    assert_eq!(next.len(), n);
    assert_eq!(alive.len(), n);
    if n == 0 || rounds == 0 {
        return start_round;
    }
    let elems = z[0].rows * z[0].cols + usize::from(scalar.is_some());
    let mat_rows = z[0].rows;
    for k in 0..rounds {
        let round = start_round + k as u64;
        plan.fill_alive_mask(round, alive);
        if k == 0 || plan.membership_changes_at(round) {
            *awm = active_local_degree_weights(g, alive);
        }
        {
            let src: &[Mat] = z.as_slice();
            let dst = views.fill(next.as_mut_slice());
            let (awm, alive): (&WeightMatrix, &[bool]) = (awm, alive);
            match &mut scalar {
                Some((w_src, w_dst)) => {
                    let ws: &[f64] = w_src.as_slice();
                    let wd = DisjointSlice::new(w_dst.as_mut_slice());
                    pool.run_chunks2(n, &|_| mat_rows, &|i, lo, hi| {
                        // SAFETY: rows [lo, hi) of node i belong to
                        // exactly one task; the scalar slot is written
                        // only by the task owning the first rows.
                        let d = unsafe { dst.rows_mut(i, lo, hi) };
                        mix_node_rows_faulty(g, awm, plan, round, alive, src, i, lo, hi, d);
                        if lo == 0 {
                            // SAFETY: slot i is written only by the task
                            // owning the first rows of node i.
                            unsafe {
                                *wd.get_mut(i) =
                                    mix_scalar_faulty(g, awm, plan, round, alive, ws, i)
                            };
                        }
                    });
                }
                None => {
                    pool.run_chunks2(n, &|_| mat_rows, &|i, lo, hi| {
                        // SAFETY: rows [lo, hi) of node i belong to
                        // exactly one task.
                        let d = unsafe { dst.rows_mut(i, lo, hi) };
                        mix_node_rows_faulty(g, awm, plan, round, alive, src, i, lo, hi, d);
                    });
                }
            }
        }
        for i in 0..n {
            if !alive[i] {
                continue;
            }
            let msgs = g.adj[i]
                .iter()
                .filter(|&&j| alive[j] && !plan.edge_cut(round, i, j))
                .count() as u64;
            counters.record_sends(i, msgs, elems);
        }
        std::mem::swap(z, next);
        if let Some((w_src, w_dst)) = &mut scalar {
            std::mem::swap(*w_src, *w_dst);
        }
    }
    start_round + rounds as u64
}

/// Run `rounds` synchronous consensus iterations in place:
/// `Z_i ← w_ii Z_i + Σ_{j∈adj(i)} w_ij Z_j`.
///
/// Each round, every node sends its current matrix to each neighbor
/// (`deg(i)` messages), matching MPI blocking point-to-point exchanges.
/// Convenience wrapper over [`consensus_rounds`] that allocates its own
/// double buffer and runs serially; the zero-allocation path is
/// `SyncNetwork::consensus`, which owns a persistent workspace and pool.
pub fn average_consensus(
    g: &Graph,
    wm: &WeightMatrix,
    z: &mut Vec<Mat>,
    rounds: usize,
    counters: &mut P2pCounters,
) -> ConsensusOutcome {
    let mut next: Vec<Mat> = z.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect();
    let mut views = MatRowsScratch::new();
    consensus_rounds(
        g,
        wm,
        z,
        &mut next,
        None,
        rounds,
        counters,
        &NodePool::serial(),
        &mut views,
    )
}

/// Alg. 1 step 11: rescale each node's consensus result by `[W^{T_c} e_1]_i`
/// so the (approximate) network average becomes an estimate of the **sum**.
///
/// For very small round counts (SA-DOT's first iterations under a `0.5t+1`
/// schedule), nodes farther than `T_c` hops from node 0 have
/// `[W^{T_c} e_1]_i = 0`; the paper's formula is undefined there. We use
/// the asymptotically equivalent rescale ×N in that regime — early OI
/// iterates are dominated by consensus error anyway (the premise of
/// SA-DOT), and the choice washes out as `T_c(t)` grows.
pub fn rescale_to_sum(wm: &WeightMatrix, z: &mut [Mat], rounds: usize) {
    let v = wm.pow_e1(rounds);
    let n = z.len() as f64;
    for (i, m) in z.iter_mut().enumerate() {
        let s = v[i];
        if s > 1e-9 {
            m.scale_inplace(1.0 / s);
        } else {
            m.scale_inplace(n);
        }
    }
}

/// Exact average (what infinite consensus would produce) — used by tests
/// and by the F-DOT push-sum fallback.
pub fn exact_average(z: &[Mat]) -> Mat {
    assert!(!z.is_empty());
    let mut sum = Mat::zeros(z[0].rows, z[0].cols);
    for m in z {
        sum.axpy(1.0, m);
    }
    sum.scale_inplace(1.0 / z.len() as f64);
    sum
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consensus::weights::local_degree_weights;
    use crate::util::rng::Rng;

    fn setup(n: usize, p: f64, seed: u64) -> (Graph, WeightMatrix, Vec<Mat>, Rng) {
        let mut rng = Rng::new(seed);
        let g = Graph::erdos_renyi(n, p, &mut rng);
        let wm = local_degree_weights(&g);
        let z: Vec<Mat> = (0..n).map(|_| Mat::gauss(6, 3, &mut rng)).collect();
        (g, wm, z, rng)
    }

    #[test]
    fn consensus_converges_to_average() {
        let (g, wm, mut z, _) = setup(12, 0.4, 1);
        let avg = exact_average(&z);
        let mut c = P2pCounters::new(12);
        average_consensus(&g, &wm, &mut z, 400, &mut c);
        for zi in &z {
            assert!(zi.dist_fro(&avg) < 1e-8);
        }
    }

    #[test]
    fn consensus_preserves_network_sum() {
        let (g, wm, mut z, _) = setup(10, 0.5, 2);
        let sum_before = {
            let mut s = Mat::zeros(6, 3);
            z.iter().for_each(|m| s.axpy(1.0, m));
            s
        };
        let mut c = P2pCounters::new(10);
        average_consensus(&g, &wm, &mut z, 17, &mut c);
        let mut sum_after = Mat::zeros(6, 3);
        z.iter().for_each(|m| sum_after.axpy(1.0, m));
        assert!(sum_before.dist_fro(&sum_after) < 1e-9);
    }

    #[test]
    fn p2p_counts_match_degrees() {
        let (g, wm, mut z, _) = setup(9, 0.4, 3);
        let rounds = 23;
        let mut c = P2pCounters::new(9);
        average_consensus(&g, &wm, &mut z, rounds, &mut c);
        for i in 0..9 {
            assert_eq!(c.sent[i], (rounds * g.degree(i)) as u64);
        }
    }

    #[test]
    fn zero_rounds_is_noop() {
        let (g, wm, mut z, _) = setup(8, 0.5, 4);
        let before = z.clone();
        let mut c = P2pCounters::new(8);
        average_consensus(&g, &wm, &mut z, 0, &mut c);
        for (a, b) in z.iter().zip(before.iter()) {
            assert_eq!(a.data, b.data);
        }
        assert_eq!(c.total(), 0);
    }

    #[test]
    fn rescale_recovers_sum() {
        let (g, wm, mut z, _) = setup(11, 0.5, 5);
        let mut total = Mat::zeros(6, 3);
        z.iter().for_each(|m| total.axpy(1.0, m));
        let rounds = 300;
        let mut c = P2pCounters::new(11);
        average_consensus(&g, &wm, &mut z, rounds, &mut c);
        rescale_to_sum(&wm, &mut z, rounds);
        for zi in &z {
            assert!(zi.dist_fro(&total) < 1e-6 * total.fro_norm().max(1.0));
        }
    }

    #[test]
    fn rescale_finite_rounds_still_useful() {
        // With few rounds the rescaled estimate is inexact but finite and
        // in the right ballpark (Proposition 1 behaviour).
        let (g, wm, mut z, _) = setup(10, 0.4, 6);
        let mut total = Mat::zeros(6, 3);
        z.iter().for_each(|m| total.axpy(1.0, m));
        let rounds = 30;
        let mut c = P2pCounters::new(10);
        average_consensus(&g, &wm, &mut z, rounds, &mut c);
        rescale_to_sum(&wm, &mut z, rounds);
        for zi in &z {
            assert!(zi.is_finite());
            assert!(zi.dist_fro(&total) < 0.5 * total.fro_norm().max(1.0));
        }
    }

    #[test]
    fn faulty_rounds_with_trivial_plan_match_normal_bitwise() {
        let (g, wm, z0, _) = setup(10, 0.4, 8);
        let rounds = 21;

        let mut z_a = z0.clone();
        let mut next_a: Vec<Mat> = z_a.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect();
        let mut c_a = P2pCounters::new(10);
        let mut views_a = MatRowsScratch::new();
        consensus_rounds(
            &g,
            &wm,
            &mut z_a,
            &mut next_a,
            None,
            rounds,
            &mut c_a,
            &NodePool::serial(),
            &mut views_a,
        );

        let plan = FaultPlan::none();
        let mut alive = vec![true; 10];
        let mut awm = local_degree_weights(&g);
        let mut z_b = z0.clone();
        let mut next_b: Vec<Mat> = z_b.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect();
        let mut c_b = P2pCounters::new(10);
        let mut views_b = MatRowsScratch::new();
        let end = faulty_consensus_rounds(
            &g,
            &plan,
            0,
            &mut alive,
            &mut awm,
            &mut z_b,
            &mut next_b,
            None,
            rounds,
            &mut c_b,
            &NodePool::serial(),
            &mut views_b,
        );
        assert_eq!(end, rounds as u64);
        for (a, b) in z_a.iter().zip(&z_b) {
            assert_eq!(a.data, b.data, "trivial plan must not change a single bit");
        }
        assert_eq!(c_a.sent, c_b.sent);
        assert_eq!(c_a.payload, c_b.payload);
    }

    #[test]
    fn faulty_rounds_dead_node_freezes_and_survivors_average() {
        let mut rng = Rng::new(10);
        let g = Graph::complete(8);
        let z0: Vec<Mat> = (0..8).map(|_| Mat::gauss(5, 2, &mut rng)).collect();
        let plan = FaultPlan::none().with_node_down(3, 0);
        let mut alive = vec![true; 8];
        let mut awm = local_degree_weights(&g);
        let mut z = z0.clone();
        let mut next: Vec<Mat> = z.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect();
        let mut c = P2pCounters::new(8);
        let mut views = MatRowsScratch::new();
        faulty_consensus_rounds(
            &g,
            &plan,
            0,
            &mut alive,
            &mut awm,
            &mut z,
            &mut next,
            None,
            400,
            &mut c,
            &NodePool::serial(),
            &mut views,
        );
        assert_eq!(z[3].data, z0[3].data, "a dead node's estimate freezes");
        assert_eq!(c.sent[3], 0, "a dead node sends nothing");
        let mut avg = Mat::zeros(5, 2);
        for (i, m) in z0.iter().enumerate() {
            if i != 3 {
                avg.axpy(1.0, m);
            }
        }
        avg.scale_inplace(1.0 / 7.0);
        for (i, zi) in z.iter().enumerate() {
            if i != 3 {
                assert!(zi.dist_fro(&avg) < 1e-8, "survivor {i} must reach survivors' avg");
            }
        }
        // Every survivor lost exactly one neighbor: 6 sends per round.
        for i in 0..8 {
            if i != 3 {
                assert_eq!(c.sent[i], 400 * 6);
            }
        }
    }

    #[test]
    fn faulty_rounds_under_loss_stay_row_stochastic_bounded() {
        // 20% directed message loss: realized mixing stays row-stochastic
        // (self-substitution), so iterates remain within the initial
        // coordinate-wise hull — no blow-up, no NaN.
        let mut rng = Rng::new(11);
        let g = Graph::ring(9);
        let z0: Vec<Mat> = (0..9).map(|_| Mat::gauss(4, 2, &mut rng)).collect();
        let plan = FaultPlan::none().with_loss(0.2, 33);
        let hi = z0.iter().map(|m| m.max_abs()).fold(0.0f64, f64::max);
        let mut alive = vec![true; 9];
        let mut awm = local_degree_weights(&g);
        let mut z = z0.clone();
        let mut next: Vec<Mat> = z.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect();
        let mut c = P2pCounters::new(9);
        let mut views = MatRowsScratch::new();
        faulty_consensus_rounds(
            &g,
            &plan,
            0,
            &mut alive,
            &mut awm,
            &mut z,
            &mut next,
            None,
            200,
            &mut c,
            &NodePool::serial(),
            &mut views,
        );
        for zi in &z {
            assert!(zi.is_finite());
            assert!(zi.max_abs() <= hi + 1e-12);
        }
        // Loss does not change send accounting (messages were transmitted).
        for i in 0..9 {
            assert_eq!(c.sent[i], 200 * 2);
        }
    }

    #[test]
    fn consensus_error_decays_monotonically_in_rounds() {
        let (g, wm, z0, _) = setup(14, 0.3, 7);
        let avg = exact_average(&z0);
        let mut errs = Vec::new();
        for rounds in [5usize, 20, 80] {
            let mut z = z0.clone();
            let mut c = P2pCounters::new(14);
            average_consensus(&g, &wm, &mut z, rounds, &mut c);
            let worst = z
                .iter()
                .map(|m| m.dist_fro(&avg))
                .fold(0.0f64, f64::max);
            errs.push(worst);
        }
        assert!(errs[0] > errs[1] && errs[1] > errs[2], "{errs:?}");
    }
}
