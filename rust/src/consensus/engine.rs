//! The consensus-averaging inner loop (Alg. 1 steps 6–11).
//!
//! Operates on one matrix per node and mixes them through the weight
//! matrix using **only graph-neighbor state** — the simulator enforces the
//! communication structure the algorithm would have on a real network, and
//! every neighbor exchange increments the P2P counters.

use super::weights::{active_local_degree_weights, SparseWeights, WeightMatrix};
use crate::fault::FaultPlan;
use crate::graph::Graph;
use crate::linalg::Mat;
use crate::network::counters::P2pCounters;
use crate::runtime::pool::{DisjointSlice, NodePool};
use crate::runtime::workspace::MatRowsScratch;

/// Result of a consensus run.
#[derive(Clone, Debug)]
pub struct ConsensusOutcome {
    pub rounds: usize,
}

/// Rows `lo..hi` of one node's synchronous mixing update:
/// `dst ← w_ii src_i + Σ_{j∈adj(i)} w_ij src_j`.
///
/// Per-element operation order (copy, scale by `w_ii`, then one axpy per
/// neighbor in adjacency order) matches the historical whole-matrix
/// update exactly, so any row split assembles to the serial result
/// bitwise — the property that lets large-d mixing fan across leftover
/// threads when N < threads.
#[inline]
fn mix_node_rows(
    g: &Graph,
    wm: &WeightMatrix,
    src: &[Mat],
    i: usize,
    lo: usize,
    hi: usize,
    dst_rows: &mut [f64],
) {
    let cols = src[i].cols;
    let (s0, s1) = (lo * cols, hi * cols);
    let wii = wm.w.get(i, i);
    dst_rows.copy_from_slice(&src[i].data[s0..s1]);
    for v in dst_rows.iter_mut() {
        *v *= wii;
    }
    for &j in &g.adj[i] {
        let w = wm.w.get(i, j);
        for (d, &s) in dst_rows.iter_mut().zip(src[j].data[s0..s1].iter()) {
            *d += w * s;
        }
    }
}

/// The matching update for the push-sum scalar weight channel.
#[inline]
fn mix_scalar(g: &Graph, wm: &WeightMatrix, src: &[f64], i: usize) -> f64 {
    let mut s = wm.w.get(i, i) * src[i];
    for &j in &g.adj[i] {
        s += wm.w.get(i, j) * src[j];
    }
    s
}

/// The shared mixing engine: `rounds` synchronous consensus iterations
/// over a caller-provided double buffer, optionally carrying the
/// push-sum scalar weight channel in the same message (ratio consensus).
///
/// This is the single mixing kernel behind both [`average_consensus`]
/// and `SyncNetwork::ratio_consensus_sum` — mixing within a round fans
/// out across `pool` hierarchically (node chunks first, then rows of
/// each node's matrix when threads are left over — bitwise deterministic
/// for any thread count; see `runtime::pool`), and P2P accounting lives
/// in one place: each round node `i` sends `deg(i)` messages of
/// `rows·cols` elements, `+1` when the scalar channel rides along.
#[allow(clippy::too_many_arguments)]
pub fn consensus_rounds(
    g: &Graph,
    wm: &WeightMatrix,
    z: &mut Vec<Mat>,
    next: &mut Vec<Mat>,
    mut scalar: Option<(&mut Vec<f64>, &mut Vec<f64>)>,
    rounds: usize,
    counters: &mut P2pCounters,
    pool: &NodePool,
    views: &mut MatRowsScratch,
) -> ConsensusOutcome {
    let n = g.n;
    assert_eq!(z.len(), n);
    assert_eq!(next.len(), n);
    assert_eq!(wm.n(), n);
    if n == 0 || rounds == 0 {
        return ConsensusOutcome { rounds: 0 };
    }
    let elems = z[0].rows * z[0].cols + usize::from(scalar.is_some());
    let mat_rows = z[0].rows;
    for _round in 0..rounds {
        {
            let src: &[Mat] = z.as_slice();
            let dst = views.fill(next.as_mut_slice());
            match &mut scalar {
                Some((w_src, w_dst)) => {
                    let ws: &[f64] = w_src.as_slice();
                    let wd = DisjointSlice::new(w_dst.as_mut_slice());
                    pool.run_chunks2(n, &|_| mat_rows, &|i, lo, hi| {
                        // SAFETY: rows [lo, hi) of node i belong to
                        // exactly one task; the scalar slot is written
                        // only by the task owning the first rows.
                        let d = unsafe { dst.rows_mut(i, lo, hi) };
                        mix_node_rows(g, wm, src, i, lo, hi, d);
                        if lo == 0 {
                            // SAFETY: slot i is written only by the task
                            // owning the first rows of node i.
                            unsafe { *wd.get_mut(i) = mix_scalar(g, wm, ws, i) };
                        }
                    });
                }
                None => {
                    pool.run_chunks2(n, &|_| mat_rows, &|i, lo, hi| {
                        // SAFETY: rows [lo, hi) of node i belong to
                        // exactly one task.
                        let d = unsafe { dst.rows_mut(i, lo, hi) };
                        mix_node_rows(g, wm, src, i, lo, hi, d);
                    });
                }
            }
        }
        for i in 0..n {
            // i sends one matrix to each neighbor (the read of z[j] above
            // is the receive side of j's send).
            counters.record_sends(i, g.degree(i) as u64, elems);
        }
        std::mem::swap(z, next);
        if let Some((w_src, w_dst)) = &mut scalar {
            std::mem::swap(*w_src, *w_dst);
        }
    }
    ConsensusOutcome { rounds }
}

/// Rejoin warm-start rule (PR 6 follow-on): a node returning from a
/// down period holds a frozen pre-drop estimate that would drag the
/// masked eq. 11 average; on its rejoin round it instead **adopts the
/// lowest-rank alive neighbor's estimate** (adjacency lists are sorted,
/// so "first alive neighbor" is "lowest id"). Returns `Some(source)`
/// when `round` is node `i`'s rejoin round — the node to copy from, or
/// `i` itself when no alive neighbor exists or the chosen neighbor's
/// message was severed/lost this round (in the MPI runtime the warm-start
/// source is whatever landed in the inbox, so the fallback must key off
/// the same delivery verdicts). Pure in `(plan, round, alive, i)`: the
/// detection uses the plan's previous-round membership rather than any
/// carried state, so checkpoint/resume and row splits stay bitwise.
#[inline]
fn rejoin_source(
    g: &Graph,
    plan: &FaultPlan,
    round: u64,
    alive: &[bool],
    i: usize,
) -> Option<usize> {
    if round == 0 || !plan.node_down(i, round - 1) {
        return None;
    }
    let pick = g.adj[i].iter().copied().find(|&j| alive[j]);
    Some(match pick {
        Some(j) if !plan.edge_cut(round, i, j) && !plan.msg_lost(round, j, i) => j,
        _ => i,
    })
}

/// Rows `lo..hi` of one node's mixing update under an active
/// [`FaultPlan`]: a dead node freezes (`dst ← src_i`); a node on its
/// rejoin round warm-starts from a live neighbor ([`rejoin_source`]); an
/// alive node mixes with the **active-subgraph** weights, substituting
/// its own value for any neighbor message severed by a partition or
/// dropped by the loss coin (`dst += w_ij src_i` instead of
/// `w_ij src_j`). The self-substitution keeps every realized row
/// stochastic, so iterates stay bounded under arbitrary loss. All fault
/// verdicts are pure functions of `(plan, round, i, j)`, so any row
/// split still assembles to the serial result bitwise.
#[allow(clippy::too_many_arguments)]
#[inline]
fn mix_node_rows_faulty(
    g: &Graph,
    awm: &WeightMatrix,
    plan: &FaultPlan,
    round: u64,
    alive: &[bool],
    src: &[Mat],
    i: usize,
    lo: usize,
    hi: usize,
    dst_rows: &mut [f64],
) {
    let cols = src[i].cols;
    let (s0, s1) = (lo * cols, hi * cols);
    dst_rows.copy_from_slice(&src[i].data[s0..s1]);
    if !alive[i] {
        return;
    }
    if let Some(from) = rejoin_source(g, plan, round, alive, i) {
        dst_rows.copy_from_slice(&src[from].data[s0..s1]);
        return;
    }
    let wii = awm.w.get(i, i);
    for v in dst_rows.iter_mut() {
        *v *= wii;
    }
    for &j in &g.adj[i] {
        if !alive[j] {
            continue; // w_ij is 0 in the active weights
        }
        let w = awm.w.get(i, j);
        let from = if plan.edge_cut(round, i, j) || plan.msg_lost(round, j, i) {
            i // message j → i did not arrive: fold w_ij onto own value
        } else {
            j
        };
        for (d, &s) in dst_rows.iter_mut().zip(src[from].data[s0..s1].iter()) {
            *d += w * s;
        }
    }
}

/// The matching faulty update for the push-sum scalar channel.
#[inline]
fn mix_scalar_faulty(
    g: &Graph,
    awm: &WeightMatrix,
    plan: &FaultPlan,
    round: u64,
    alive: &[bool],
    src: &[f64],
    i: usize,
) -> f64 {
    if !alive[i] {
        return src[i];
    }
    if let Some(from) = rejoin_source(g, plan, round, alive, i) {
        return src[from];
    }
    let mut s = awm.w.get(i, i) * src[i];
    for &j in &g.adj[i] {
        if !alive[j] {
            continue;
        }
        let w = awm.w.get(i, j);
        let from =
            if plan.edge_cut(round, i, j) || plan.msg_lost(round, j, i) { i } else { j };
        s += w * src[from];
    }
    s
}

/// The fault-tolerant sibling of [`consensus_rounds`]: `rounds`
/// synchronous iterations under a [`FaultPlan`], starting at the global
/// consensus-round stamp `start_round` (the simulator's virtual clock).
///
/// Membership is re-evaluated every round and the Metropolis–Hastings
/// weights are re-normalized on the surviving subgraph at every
/// membership epoch (graceful degradation instead of a panic). The
/// optional `scalar` channel rides along under **identical** fault
/// verdicts — `SyncNetwork::consensus_sum` seeds it with `e₁` so the
/// Alg. 1 step-11 rescale tracks the *realized* time-varying mixing
/// product rather than a fixed `W^{T_c}`.
///
/// Counters: an alive node sends to each alive, non-partitioned
/// neighbor; a message eaten by the loss coin still counts (it was
/// transmitted), while a severed link or dead endpoint sends nothing.
/// This path may allocate (weights re-normalization at epochs) — the
/// zero-allocation contract covers only the fault-free path, which is
/// untouched.
///
/// Returns the advanced round stamp (`start_round + rounds`).
#[allow(clippy::too_many_arguments)]
pub fn faulty_consensus_rounds(
    g: &Graph,
    plan: &FaultPlan,
    start_round: u64,
    alive: &mut [bool],
    awm: &mut WeightMatrix,
    z: &mut Vec<Mat>,
    next: &mut Vec<Mat>,
    mut scalar: Option<(&mut Vec<f64>, &mut Vec<f64>)>,
    rounds: usize,
    counters: &mut P2pCounters,
    pool: &NodePool,
    views: &mut MatRowsScratch,
) -> u64 {
    let n = g.n;
    assert_eq!(z.len(), n);
    assert_eq!(next.len(), n);
    assert_eq!(alive.len(), n);
    if n == 0 || rounds == 0 {
        return start_round;
    }
    let elems = z[0].rows * z[0].cols + usize::from(scalar.is_some());
    let mat_rows = z[0].rows;
    for k in 0..rounds {
        let round = start_round + k as u64;
        plan.fill_alive_mask(round, alive);
        if k == 0 || plan.membership_changes_at(round) {
            *awm = active_local_degree_weights(g, alive);
        }
        {
            let src: &[Mat] = z.as_slice();
            let dst = views.fill(next.as_mut_slice());
            let (awm, alive): (&WeightMatrix, &[bool]) = (awm, alive);
            match &mut scalar {
                Some((w_src, w_dst)) => {
                    let ws: &[f64] = w_src.as_slice();
                    let wd = DisjointSlice::new(w_dst.as_mut_slice());
                    pool.run_chunks2(n, &|_| mat_rows, &|i, lo, hi| {
                        // SAFETY: rows [lo, hi) of node i belong to
                        // exactly one task; the scalar slot is written
                        // only by the task owning the first rows.
                        let d = unsafe { dst.rows_mut(i, lo, hi) };
                        mix_node_rows_faulty(g, awm, plan, round, alive, src, i, lo, hi, d);
                        if lo == 0 {
                            // SAFETY: slot i is written only by the task
                            // owning the first rows of node i.
                            unsafe {
                                *wd.get_mut(i) =
                                    mix_scalar_faulty(g, awm, plan, round, alive, ws, i)
                            };
                        }
                    });
                }
                None => {
                    pool.run_chunks2(n, &|_| mat_rows, &|i, lo, hi| {
                        // SAFETY: rows [lo, hi) of node i belong to
                        // exactly one task.
                        let d = unsafe { dst.rows_mut(i, lo, hi) };
                        mix_node_rows_faulty(g, awm, plan, round, alive, src, i, lo, hi, d);
                    });
                }
            }
        }
        for i in 0..n {
            if !alive[i] {
                continue;
            }
            let msgs = g.adj[i]
                .iter()
                .filter(|&&j| alive[j] && !plan.edge_cut(round, i, j))
                .count() as u64;
            counters.record_sends(i, msgs, elems);
        }
        std::mem::swap(z, next);
        if let Some((w_src, w_dst)) = &mut scalar {
            std::mem::swap(*w_src, *w_dst);
        }
    }
    start_round + rounds as u64
}

/// Rows `lo..hi` of one node's mixing update off **sparse** weights —
/// the O(deg(i)) production kernel. Identical per-element operation
/// order to [`mix_node_rows`] (copy, scale by the diagonal, one axpy per
/// neighbor in adjacency order), and [`SparseWeights`] rows mirror
/// `Graph::adj` element-for-element, so the result is **bitwise
/// identical** to the dense kernel while never touching an N×N matrix.
#[inline]
fn sparse_mix_node_rows(
    sw: &SparseWeights,
    src: &[Mat],
    i: usize,
    lo: usize,
    hi: usize,
    dst_rows: &mut [f64],
) {
    let cols = src[i].cols;
    let (s0, s1) = (lo * cols, hi * cols);
    let wii = sw.diag[i];
    dst_rows.copy_from_slice(&src[i].data[s0..s1]);
    for v in dst_rows.iter_mut() {
        *v *= wii;
    }
    let (ncols, nvals) = sw.row(i);
    for (&j, &w) in ncols.iter().zip(nvals.iter()) {
        for (d, &s) in dst_rows.iter_mut().zip(src[j].data[s0..s1].iter()) {
            *d += w * s;
        }
    }
}

/// The matching sparse update for the push-sum scalar weight channel.
#[inline]
fn sparse_mix_scalar(sw: &SparseWeights, src: &[f64], i: usize) -> f64 {
    let mut s = sw.diag[i] * src[i];
    let (ncols, nvals) = sw.row(i);
    for (&j, &w) in ncols.iter().zip(nvals.iter()) {
        s += w * src[j];
    }
    s
}

/// Sparse sibling of [`consensus_rounds`] — one round costs O(edges)
/// plus the matrix arithmetic, never O(N²). Bitwise identical to the
/// dense engine for any weight matrix whose graph-structured entries
/// `sw` carries (pinned per topology family by tests below), and
/// allocation-free after warm-up: the kernel writes through the caller's
/// double buffer and view scratch only.
#[allow(clippy::too_many_arguments)]
pub fn sparse_consensus_rounds(
    sw: &SparseWeights,
    z: &mut Vec<Mat>,
    next: &mut Vec<Mat>,
    mut scalar: Option<(&mut Vec<f64>, &mut Vec<f64>)>,
    rounds: usize,
    counters: &mut P2pCounters,
    pool: &NodePool,
    views: &mut MatRowsScratch,
) -> ConsensusOutcome {
    let n = sw.n();
    assert_eq!(z.len(), n);
    assert_eq!(next.len(), n);
    if n == 0 || rounds == 0 {
        return ConsensusOutcome { rounds: 0 };
    }
    let elems = z[0].rows * z[0].cols + usize::from(scalar.is_some());
    let mat_rows = z[0].rows;
    for _round in 0..rounds {
        {
            let src: &[Mat] = z.as_slice();
            let dst = views.fill(next.as_mut_slice());
            match &mut scalar {
                Some((w_src, w_dst)) => {
                    let ws: &[f64] = w_src.as_slice();
                    let wd = DisjointSlice::new(w_dst.as_mut_slice());
                    pool.run_chunks2(n, &|_| mat_rows, &|i, lo, hi| {
                        // SAFETY: rows [lo, hi) of node i belong to
                        // exactly one task; the scalar slot is written
                        // only by the task owning the first rows.
                        let d = unsafe { dst.rows_mut(i, lo, hi) };
                        sparse_mix_node_rows(sw, src, i, lo, hi, d);
                        if lo == 0 {
                            // SAFETY: slot i is written only by the task
                            // owning the first rows of node i.
                            unsafe { *wd.get_mut(i) = sparse_mix_scalar(sw, ws, i) };
                        }
                    });
                }
                None => {
                    pool.run_chunks2(n, &|_| mat_rows, &|i, lo, hi| {
                        // SAFETY: rows [lo, hi) of node i belong to
                        // exactly one task.
                        let d = unsafe { dst.rows_mut(i, lo, hi) };
                        sparse_mix_node_rows(sw, src, i, lo, hi, d);
                    });
                }
            }
        }
        for i in 0..n {
            // deg(i) is row i's stored-entry count — no graph needed.
            counters.record_sends(i, (sw.off[i + 1] - sw.off[i]) as u64, elems);
        }
        std::mem::swap(z, next);
        if let Some((w_src, w_dst)) = &mut scalar {
            std::mem::swap(*w_src, *w_dst);
        }
    }
    ConsensusOutcome { rounds }
}

/// Sparse faulty row kernel. `asw` holds the **active** weights
/// ([`SparseWeights::refresh_active`]); dead neighbors are skipped via
/// the alive mask exactly like the dense kernel — never by multiplying
/// the stored zero through, which would break bit-parity (`d + 0.0·s`
/// is not a no-op when `d == -0.0`). Rejoin rounds warm-start through
/// the same [`rejoin_source`] rule as the dense kernel.
#[allow(clippy::too_many_arguments)]
#[inline]
fn sparse_mix_node_rows_faulty(
    g: &Graph,
    asw: &SparseWeights,
    plan: &FaultPlan,
    round: u64,
    alive: &[bool],
    src: &[Mat],
    i: usize,
    lo: usize,
    hi: usize,
    dst_rows: &mut [f64],
) {
    let cols = src[i].cols;
    let (s0, s1) = (lo * cols, hi * cols);
    dst_rows.copy_from_slice(&src[i].data[s0..s1]);
    if !alive[i] {
        return;
    }
    if let Some(from) = rejoin_source(g, plan, round, alive, i) {
        dst_rows.copy_from_slice(&src[from].data[s0..s1]);
        return;
    }
    let wii = asw.diag[i];
    for v in dst_rows.iter_mut() {
        *v *= wii;
    }
    let (ncols, nvals) = asw.row(i);
    for (&j, &w) in ncols.iter().zip(nvals.iter()) {
        if !alive[j] {
            continue; // stored weight is 0 — skip, don't multiply through
        }
        let from = if plan.edge_cut(round, i, j) || plan.msg_lost(round, j, i) {
            i // message j → i did not arrive: fold w_ij onto own value
        } else {
            j
        };
        for (d, &s) in dst_rows.iter_mut().zip(src[from].data[s0..s1].iter()) {
            *d += w * s;
        }
    }
}

/// The matching sparse faulty update for the push-sum scalar channel.
#[inline]
fn sparse_mix_scalar_faulty(
    g: &Graph,
    asw: &SparseWeights,
    plan: &FaultPlan,
    round: u64,
    alive: &[bool],
    src: &[f64],
    i: usize,
) -> f64 {
    if !alive[i] {
        return src[i];
    }
    if let Some(from) = rejoin_source(g, plan, round, alive, i) {
        return src[from];
    }
    let mut s = asw.diag[i] * src[i];
    let (ncols, nvals) = asw.row(i);
    for (&j, &w) in ncols.iter().zip(nvals.iter()) {
        if !alive[j] {
            continue;
        }
        let from =
            if plan.edge_cut(round, i, j) || plan.msg_lost(round, j, i) { i } else { j };
        s += w * src[from];
    }
    s
}

/// Sparse sibling of [`faulty_consensus_rounds`] — the event-driven
/// fault path: membership is re-evaluated every round, but the active
/// Metropolis–Hastings weights are re-derived **in place** (O(active
/// edges), buffer-reusing) only at membership epochs, so steady rounds
/// between epochs cost O(active edges) with no N² scan and no
/// allocation beyond the first epoch's scratch growth. Bitwise identical
/// to the dense faulty engine for every plan (same kernels, same
/// verdicts, same weight values).
///
/// Returns the advanced round stamp (`start_round + rounds`).
#[allow(clippy::too_many_arguments)]
pub fn sparse_faulty_consensus_rounds(
    g: &Graph,
    plan: &FaultPlan,
    start_round: u64,
    alive: &mut [bool],
    asw: &mut SparseWeights,
    z: &mut Vec<Mat>,
    next: &mut Vec<Mat>,
    mut scalar: Option<(&mut Vec<f64>, &mut Vec<f64>)>,
    rounds: usize,
    counters: &mut P2pCounters,
    pool: &NodePool,
    views: &mut MatRowsScratch,
) -> u64 {
    let n = g.n;
    assert_eq!(z.len(), n);
    assert_eq!(next.len(), n);
    assert_eq!(alive.len(), n);
    if n == 0 || rounds == 0 {
        return start_round;
    }
    let elems = z[0].rows * z[0].cols + usize::from(scalar.is_some());
    let mat_rows = z[0].rows;
    for k in 0..rounds {
        let round = start_round + k as u64;
        plan.fill_alive_mask(round, alive);
        if k == 0 || plan.membership_changes_at(round) {
            asw.refresh_active(g, alive);
        }
        {
            let src: &[Mat] = z.as_slice();
            let dst = views.fill(next.as_mut_slice());
            let (asw, alive): (&SparseWeights, &[bool]) = (asw, alive);
            match &mut scalar {
                Some((w_src, w_dst)) => {
                    let ws: &[f64] = w_src.as_slice();
                    let wd = DisjointSlice::new(w_dst.as_mut_slice());
                    pool.run_chunks2(n, &|_| mat_rows, &|i, lo, hi| {
                        // SAFETY: rows [lo, hi) of node i belong to
                        // exactly one task; the scalar slot is written
                        // only by the task owning the first rows.
                        let d = unsafe { dst.rows_mut(i, lo, hi) };
                        sparse_mix_node_rows_faulty(g, asw, plan, round, alive, src, i, lo, hi, d);
                        if lo == 0 {
                            // SAFETY: slot i is written only by the task
                            // owning the first rows of node i.
                            unsafe {
                                *wd.get_mut(i) =
                                    sparse_mix_scalar_faulty(g, asw, plan, round, alive, ws, i)
                            };
                        }
                    });
                }
                None => {
                    pool.run_chunks2(n, &|_| mat_rows, &|i, lo, hi| {
                        // SAFETY: rows [lo, hi) of node i belong to
                        // exactly one task.
                        let d = unsafe { dst.rows_mut(i, lo, hi) };
                        sparse_mix_node_rows_faulty(g, asw, plan, round, alive, src, i, lo, hi, d);
                    });
                }
            }
        }
        for i in 0..n {
            if !alive[i] {
                continue;
            }
            let msgs = g.adj[i]
                .iter()
                .filter(|&&j| alive[j] && !plan.edge_cut(round, i, j))
                .count() as u64;
            counters.record_sends(i, msgs, elems);
        }
        std::mem::swap(z, next);
        if let Some((w_src, w_dst)) = &mut scalar {
            std::mem::swap(*w_src, *w_dst);
        }
    }
    start_round + rounds as u64
}

/// Run `rounds` synchronous consensus iterations in place:
/// `Z_i ← w_ii Z_i + Σ_{j∈adj(i)} w_ij Z_j`.
///
/// Each round, every node sends its current matrix to each neighbor
/// (`deg(i)` messages), matching MPI blocking point-to-point exchanges.
/// Convenience wrapper over [`consensus_rounds`] that allocates its own
/// double buffer and runs serially; the zero-allocation path is
/// `SyncNetwork::consensus`, which owns a persistent workspace and pool.
pub fn average_consensus(
    g: &Graph,
    wm: &WeightMatrix,
    z: &mut Vec<Mat>,
    rounds: usize,
    counters: &mut P2pCounters,
) -> ConsensusOutcome {
    let mut next: Vec<Mat> = z.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect();
    let mut views = MatRowsScratch::new();
    consensus_rounds(
        g,
        wm,
        z,
        &mut next,
        None,
        rounds,
        counters,
        &NodePool::serial(),
        &mut views,
    )
}

/// Alg. 1 step 11: rescale each node's consensus result by `[W^{T_c} e_1]_i`
/// so the (approximate) network average becomes an estimate of the **sum**.
///
/// For very small round counts (SA-DOT's first iterations under a `0.5t+1`
/// schedule), nodes farther than `T_c` hops from node 0 have
/// `[W^{T_c} e_1]_i = 0`; the paper's formula is undefined there. We use
/// the asymptotically equivalent rescale ×N in that regime — early OI
/// iterates are dominated by consensus error anyway (the premise of
/// SA-DOT), and the choice washes out as `T_c(t)` grows.
pub fn rescale_to_sum(wm: &WeightMatrix, z: &mut [Mat], rounds: usize) {
    let v = wm.pow_e1(rounds);
    let n = z.len() as f64;
    for (i, m) in z.iter_mut().enumerate() {
        let s = v[i];
        if s > 1e-9 {
            m.scale_inplace(1.0 / s);
        } else {
            m.scale_inplace(n);
        }
    }
}

/// Exact average (what infinite consensus would produce) — used by tests
/// and by the F-DOT push-sum fallback.
pub fn exact_average(z: &[Mat]) -> Mat {
    assert!(!z.is_empty());
    let mut sum = Mat::zeros(z[0].rows, z[0].cols);
    for m in z {
        sum.axpy(1.0, m);
    }
    sum.scale_inplace(1.0 / z.len() as f64);
    sum
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consensus::weights::local_degree_weights;
    use crate::util::rng::Rng;

    fn setup(n: usize, p: f64, seed: u64) -> (Graph, WeightMatrix, Vec<Mat>, Rng) {
        let mut rng = Rng::new(seed);
        let g = Graph::erdos_renyi(n, p, &mut rng);
        let wm = local_degree_weights(&g);
        let z: Vec<Mat> = (0..n).map(|_| Mat::gauss(6, 3, &mut rng)).collect();
        (g, wm, z, rng)
    }

    #[test]
    fn consensus_converges_to_average() {
        let (g, wm, mut z, _) = setup(12, 0.4, 1);
        let avg = exact_average(&z);
        let mut c = P2pCounters::new(12);
        average_consensus(&g, &wm, &mut z, 400, &mut c);
        for zi in &z {
            assert!(zi.dist_fro(&avg) < 1e-8);
        }
    }

    #[test]
    fn consensus_preserves_network_sum() {
        let (g, wm, mut z, _) = setup(10, 0.5, 2);
        let sum_before = {
            let mut s = Mat::zeros(6, 3);
            z.iter().for_each(|m| s.axpy(1.0, m));
            s
        };
        let mut c = P2pCounters::new(10);
        average_consensus(&g, &wm, &mut z, 17, &mut c);
        let mut sum_after = Mat::zeros(6, 3);
        z.iter().for_each(|m| sum_after.axpy(1.0, m));
        assert!(sum_before.dist_fro(&sum_after) < 1e-9);
    }

    #[test]
    fn p2p_counts_match_degrees() {
        let (g, wm, mut z, _) = setup(9, 0.4, 3);
        let rounds = 23;
        let mut c = P2pCounters::new(9);
        average_consensus(&g, &wm, &mut z, rounds, &mut c);
        for i in 0..9 {
            assert_eq!(c.sent[i], (rounds * g.degree(i)) as u64);
        }
    }

    #[test]
    fn zero_rounds_is_noop() {
        let (g, wm, mut z, _) = setup(8, 0.5, 4);
        let before = z.clone();
        let mut c = P2pCounters::new(8);
        average_consensus(&g, &wm, &mut z, 0, &mut c);
        for (a, b) in z.iter().zip(before.iter()) {
            assert_eq!(a.data, b.data);
        }
        assert_eq!(c.total(), 0);
    }

    #[test]
    fn rescale_recovers_sum() {
        let (g, wm, mut z, _) = setup(11, 0.5, 5);
        let mut total = Mat::zeros(6, 3);
        z.iter().for_each(|m| total.axpy(1.0, m));
        let rounds = 300;
        let mut c = P2pCounters::new(11);
        average_consensus(&g, &wm, &mut z, rounds, &mut c);
        rescale_to_sum(&wm, &mut z, rounds);
        for zi in &z {
            assert!(zi.dist_fro(&total) < 1e-6 * total.fro_norm().max(1.0));
        }
    }

    #[test]
    fn rescale_finite_rounds_still_useful() {
        // With few rounds the rescaled estimate is inexact but finite and
        // in the right ballpark (Proposition 1 behaviour).
        let (g, wm, mut z, _) = setup(10, 0.4, 6);
        let mut total = Mat::zeros(6, 3);
        z.iter().for_each(|m| total.axpy(1.0, m));
        let rounds = 30;
        let mut c = P2pCounters::new(10);
        average_consensus(&g, &wm, &mut z, rounds, &mut c);
        rescale_to_sum(&wm, &mut z, rounds);
        for zi in &z {
            assert!(zi.is_finite());
            assert!(zi.dist_fro(&total) < 0.5 * total.fro_norm().max(1.0));
        }
    }

    #[test]
    fn faulty_rounds_with_trivial_plan_match_normal_bitwise() {
        let (g, wm, z0, _) = setup(10, 0.4, 8);
        let rounds = 21;

        let mut z_a = z0.clone();
        let mut next_a: Vec<Mat> = z_a.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect();
        let mut c_a = P2pCounters::new(10);
        let mut views_a = MatRowsScratch::new();
        consensus_rounds(
            &g,
            &wm,
            &mut z_a,
            &mut next_a,
            None,
            rounds,
            &mut c_a,
            &NodePool::serial(),
            &mut views_a,
        );

        let plan = FaultPlan::none();
        let mut alive = vec![true; 10];
        let mut awm = local_degree_weights(&g);
        let mut z_b = z0.clone();
        let mut next_b: Vec<Mat> = z_b.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect();
        let mut c_b = P2pCounters::new(10);
        let mut views_b = MatRowsScratch::new();
        let end = faulty_consensus_rounds(
            &g,
            &plan,
            0,
            &mut alive,
            &mut awm,
            &mut z_b,
            &mut next_b,
            None,
            rounds,
            &mut c_b,
            &NodePool::serial(),
            &mut views_b,
        );
        assert_eq!(end, rounds as u64);
        for (a, b) in z_a.iter().zip(&z_b) {
            assert_eq!(a.data, b.data, "trivial plan must not change a single bit");
        }
        assert_eq!(c_a.sent, c_b.sent);
        assert_eq!(c_a.payload, c_b.payload);
    }

    #[test]
    fn faulty_rounds_dead_node_freezes_and_survivors_average() {
        let mut rng = Rng::new(10);
        let g = Graph::complete(8);
        let z0: Vec<Mat> = (0..8).map(|_| Mat::gauss(5, 2, &mut rng)).collect();
        let plan = FaultPlan::none().with_node_down(3, 0);
        let mut alive = vec![true; 8];
        let mut awm = local_degree_weights(&g);
        let mut z = z0.clone();
        let mut next: Vec<Mat> = z.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect();
        let mut c = P2pCounters::new(8);
        let mut views = MatRowsScratch::new();
        faulty_consensus_rounds(
            &g,
            &plan,
            0,
            &mut alive,
            &mut awm,
            &mut z,
            &mut next,
            None,
            400,
            &mut c,
            &NodePool::serial(),
            &mut views,
        );
        assert_eq!(z[3].data, z0[3].data, "a dead node's estimate freezes");
        assert_eq!(c.sent[3], 0, "a dead node sends nothing");
        let mut avg = Mat::zeros(5, 2);
        for (i, m) in z0.iter().enumerate() {
            if i != 3 {
                avg.axpy(1.0, m);
            }
        }
        avg.scale_inplace(1.0 / 7.0);
        for (i, zi) in z.iter().enumerate() {
            if i != 3 {
                assert!(zi.dist_fro(&avg) < 1e-8, "survivor {i} must reach survivors' avg");
            }
        }
        // Every survivor lost exactly one neighbor: 6 sends per round.
        for i in 0..8 {
            if i != 3 {
                assert_eq!(c.sent[i], 400 * 6);
            }
        }
    }

    #[test]
    fn faulty_rounds_under_loss_stay_row_stochastic_bounded() {
        // 20% directed message loss: realized mixing stays row-stochastic
        // (self-substitution), so iterates remain within the initial
        // coordinate-wise hull — no blow-up, no NaN.
        let mut rng = Rng::new(11);
        let g = Graph::ring(9);
        let z0: Vec<Mat> = (0..9).map(|_| Mat::gauss(4, 2, &mut rng)).collect();
        let plan = FaultPlan::none().with_loss(0.2, 33);
        let hi = z0.iter().map(|m| m.max_abs()).fold(0.0f64, f64::max);
        let mut alive = vec![true; 9];
        let mut awm = local_degree_weights(&g);
        let mut z = z0.clone();
        let mut next: Vec<Mat> = z.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect();
        let mut c = P2pCounters::new(9);
        let mut views = MatRowsScratch::new();
        faulty_consensus_rounds(
            &g,
            &plan,
            0,
            &mut alive,
            &mut awm,
            &mut z,
            &mut next,
            None,
            200,
            &mut c,
            &NodePool::serial(),
            &mut views,
        );
        for zi in &z {
            assert!(zi.is_finite());
            assert!(zi.max_abs() <= hi + 1e-12);
        }
        // Loss does not change send accounting (messages were transmitted).
        for i in 0..9 {
            assert_eq!(c.sent[i], 200 * 2);
        }
    }

    #[test]
    fn consensus_error_decays_monotonically_in_rounds() {
        let (g, wm, z0, _) = setup(14, 0.3, 7);
        let avg = exact_average(&z0);
        let mut errs = Vec::new();
        for rounds in [5usize, 20, 80] {
            let mut z = z0.clone();
            let mut c = P2pCounters::new(14);
            average_consensus(&g, &wm, &mut z, rounds, &mut c);
            let worst = z
                .iter()
                .map(|m| m.dist_fro(&avg))
                .fold(0.0f64, f64::max);
            errs.push(worst);
        }
        assert!(errs[0] > errs[1] && errs[1] > errs[2], "{errs:?}");
    }

    use crate::consensus::weights::sparse_local_degree_weights;

    /// Tentpole contract: the sparse engine reproduces the dense engine
    /// bit-for-bit — matrices, scalar channel, and counters — across
    /// every `GroupTopo` family.
    #[test]
    fn sparse_rounds_bitwise_match_dense_all_topologies() {
        let mut rng = Rng::new(13);
        for spec in ["erdos", "ring", "star", "path", "complete", "grid"] {
            let g = Graph::from_spec(spec, 16, 0.35, &mut rng);
            let wm = local_degree_weights(&g);
            let sw = sparse_local_degree_weights(&g);
            let z0: Vec<Mat> = (0..g.n).map(|_| Mat::gauss(5, 3, &mut rng)).collect();
            let rounds = 19;

            let mut z_d = z0.clone();
            let mut next_d: Vec<Mat> =
                z_d.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect();
            let mut s_src_d = vec![0.0; g.n];
            s_src_d[0] = 1.0;
            let mut s_dst_d = vec![0.0; g.n];
            let mut c_d = P2pCounters::new(g.n);
            let mut views_d = MatRowsScratch::new();
            consensus_rounds(
                &g,
                &wm,
                &mut z_d,
                &mut next_d,
                Some((&mut s_src_d, &mut s_dst_d)),
                rounds,
                &mut c_d,
                &NodePool::serial(),
                &mut views_d,
            );

            let mut z_s = z0.clone();
            let mut next_s: Vec<Mat> =
                z_s.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect();
            let mut s_src_s = vec![0.0; g.n];
            s_src_s[0] = 1.0;
            let mut s_dst_s = vec![0.0; g.n];
            let mut c_s = P2pCounters::new(g.n);
            let mut views_s = MatRowsScratch::new();
            sparse_consensus_rounds(
                &sw,
                &mut z_s,
                &mut next_s,
                Some((&mut s_src_s, &mut s_dst_s)),
                rounds,
                &mut c_s,
                &NodePool::serial(),
                &mut views_s,
            );

            for (a, b) in z_d.iter().zip(&z_s) {
                for (x, y) in a.data.iter().zip(&b.data) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{spec}: matrix channel");
                }
            }
            for (x, y) in s_src_d.iter().zip(&s_src_s) {
                assert_eq!(x.to_bits(), y.to_bits(), "{spec}: scalar channel");
            }
            assert_eq!(c_d.sent, c_s.sent, "{spec}");
            assert_eq!(c_d.payload, c_s.payload, "{spec}");
        }
    }

    /// Same contract under fault plans: loss coins, churn, and a
    /// partition window all land on identical bits through the sparse
    /// faulty engine (including the epoch-driven in-place weight
    /// refresh).
    #[test]
    fn sparse_faulty_bitwise_matches_dense_all_topologies() {
        let mut rng = Rng::new(17);
        let plans = [
            FaultPlan::none(),
            FaultPlan::none().with_loss(0.25, 7),
            FaultPlan::none().with_node_churn(2, 3, 9).with_loss(0.1, 11),
            FaultPlan::none().with_partition(4, 10, vec![0, 1, 2]).with_node_down(5, 12),
        ];
        for spec in ["erdos", "ring", "star", "path", "complete", "grid"] {
            let g = Graph::from_spec(spec, 16, 0.35, &mut rng);
            let z0: Vec<Mat> = (0..g.n).map(|_| Mat::gauss(4, 2, &mut rng)).collect();
            for (pi, plan) in plans.iter().enumerate() {
                let rounds = 18;

                let mut alive_d = vec![true; g.n];
                let mut awm = local_degree_weights(&g);
                let mut z_d = z0.clone();
                let mut next_d: Vec<Mat> =
                    z_d.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect();
                let mut c_d = P2pCounters::new(g.n);
                let mut views_d = MatRowsScratch::new();
                faulty_consensus_rounds(
                    &g,
                    plan,
                    0,
                    &mut alive_d,
                    &mut awm,
                    &mut z_d,
                    &mut next_d,
                    None,
                    rounds,
                    &mut c_d,
                    &NodePool::serial(),
                    &mut views_d,
                );

                let mut alive_s = vec![true; g.n];
                let mut asw = sparse_local_degree_weights(&g);
                let mut z_s = z0.clone();
                let mut next_s: Vec<Mat> =
                    z_s.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect();
                let mut c_s = P2pCounters::new(g.n);
                let mut views_s = MatRowsScratch::new();
                sparse_faulty_consensus_rounds(
                    &g,
                    plan,
                    0,
                    &mut alive_s,
                    &mut asw,
                    &mut z_s,
                    &mut next_s,
                    None,
                    rounds,
                    &mut c_s,
                    &NodePool::serial(),
                    &mut views_s,
                );

                for (a, b) in z_d.iter().zip(&z_s) {
                    for (x, y) in a.data.iter().zip(&b.data) {
                        assert_eq!(x.to_bits(), y.to_bits(), "{spec} plan {pi}");
                    }
                }
                assert_eq!(c_d.sent, c_s.sent, "{spec} plan {pi}");
                assert_eq!(alive_d, alive_s, "{spec} plan {pi}");
            }
        }
    }

    /// Satellite regression: a rejoining node adopts its lowest-rank
    /// alive neighbor's estimate on the rejoin round instead of keeping
    /// the frozen pre-drop value, and resumes normal mixing afterwards.
    #[test]
    fn rejoin_warm_starts_from_lowest_alive_neighbor() {
        let mut rng = Rng::new(23);
        let g = Graph::complete(6);
        let z0: Vec<Mat> = (0..6).map(|_| Mat::gauss(4, 2, &mut rng)).collect();
        let plan = FaultPlan::none().with_node_churn(2, 2, 5);
        let mut alive = vec![true; 6];
        let mut awm = local_degree_weights(&g);
        let mut z = z0.clone();
        let mut next: Vec<Mat> = z.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect();
        let mut c = P2pCounters::new(6);
        let mut views = MatRowsScratch::new();
        // Rounds 0..=4: node 2 is down from round 2 through round 4.
        let stamp = faulty_consensus_rounds(
            &g,
            &plan,
            0,
            &mut alive,
            &mut awm,
            &mut z,
            &mut next,
            None,
            5,
            &mut c,
            &NodePool::serial(),
            &mut views,
        );
        assert_eq!(stamp, 5);
        let frozen = z[2].clone();
        // Lowest-rank alive neighbor of node 2 in a complete graph: 0.
        let expected = z[0].clone();
        // Round 5 is the rejoin round (down at 4, alive at 5).
        faulty_consensus_rounds(
            &g,
            &plan,
            stamp,
            &mut alive,
            &mut awm,
            &mut z,
            &mut next,
            None,
            1,
            &mut c,
            &NodePool::serial(),
            &mut views,
        );
        assert!(alive[2]);
        assert_eq!(z[2].data, expected.data, "warm-start copies neighbor 0's estimate");
        assert_ne!(z[2].data, frozen.data, "rejoin must not keep the frozen estimate");
        // After warm-start, everyone (no further faults) reaches the
        // survivors' running average as usual.
        faulty_consensus_rounds(
            &g,
            &plan,
            6,
            &mut alive,
            &mut awm,
            &mut z,
            &mut next,
            None,
            300,
            &mut c,
            &NodePool::serial(),
            &mut views,
        );
        let avg = exact_average(&z);
        for (i, zi) in z.iter().enumerate() {
            assert!(zi.dist_fro(&avg) < 1e-8, "node {i} converges after rejoin");
        }
    }

    /// The rejoin fallback keeps the frozen value when no alive neighbor
    /// exists (isolated survivor) — and stays bitwise across the sparse
    /// engine.
    #[test]
    fn rejoin_with_no_alive_neighbor_keeps_frozen_value() {
        let mut rng = Rng::new(29);
        // Path 0-1-2: node 1 rejoins while both neighbors are down.
        let g = Graph::path(3);
        let z0: Vec<Mat> = (0..3).map(|_| Mat::gauss(3, 2, &mut rng)).collect();
        let plan = FaultPlan::none()
            .with_node_churn(1, 1, 3)
            .with_node_down(0, 2)
            .with_node_down(2, 2);
        let mut alive = vec![true; 3];
        let mut awm = local_degree_weights(&g);
        let mut z = z0.clone();
        let mut next: Vec<Mat> = z.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect();
        let mut c = P2pCounters::new(3);
        let mut views = MatRowsScratch::new();
        // Rounds 0..=2 freeze node 1 from round 1; capture its value.
        faulty_consensus_rounds(
            &g,
            &plan,
            0,
            &mut alive,
            &mut awm,
            &mut z,
            &mut next,
            None,
            3,
            &mut c,
            &NodePool::serial(),
            &mut views,
        );
        let frozen = z[1].clone();
        // Round 3: node 1 rejoins, neighbors 0 and 2 are both dead.
        faulty_consensus_rounds(
            &g,
            &plan,
            3,
            &mut alive,
            &mut awm,
            &mut z,
            &mut next,
            None,
            1,
            &mut c,
            &NodePool::serial(),
            &mut views,
        );
        assert!(alive[1] && !alive[0] && !alive[2]);
        assert_eq!(z[1].data, frozen.data, "no live neighbor: keep own estimate");
    }
}
