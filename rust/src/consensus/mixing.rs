//! Mixing diagnostics of the consensus chain.
//!
//! Implements the paper's mixing-time definition (eq. 5):
//!
//! ```text
//! τ_mix = max_i  inf { t : ‖e_iᵀ W^t − (1/N) 1ᵀ‖₂ ≤ 1/2 }
//! ```
//!
//! plus the second-largest eigenvalue modulus (SLEM), whose inverse
//! log governs the asymptotic consensus rate. Ring topologies with even N
//! form a *periodic* chain under some weightings — the paper points out
//! τ_mix → ∞ there; we surface that as `None`.

use super::weights::{SparseWeights, WeightMatrix};
use crate::linalg::{sym_eig, Mat};

/// Mixing time per eq. (5). Returns `None` if not mixed after `t_max`.
pub fn mixing_time(wm: &WeightMatrix, t_max: usize) -> Option<usize> {
    let n = wm.n();
    let target = 1.0 / n as f64;
    // Track all rows of W^t at once: row i of P is e_iᵀ W^t. Each step
    // applies the sparse symmetric W to every row — O(n·edges) instead of
    // the O(n³) dense P·W matmul this replaces.
    let sw = SparseWeights::from_matrix(wm);
    let mut p = Mat::eye(n);
    let mut next = Mat::zeros(n, n);
    // Per-node first time below threshold.
    let mut hit = vec![None; n];
    for t in 1..=t_max {
        for i in 0..n {
            sw.apply(p.row(i), next.row_mut(i));
        }
        std::mem::swap(&mut p, &mut next);
        for i in 0..n {
            if hit[i].is_none() {
                let mut dev = 0.0;
                for j in 0..n {
                    let d = p.get(i, j) - target;
                    dev += d * d;
                }
                if dev.sqrt() <= 0.5 {
                    hit[i] = Some(t);
                }
            }
        }
        if hit.iter().all(|h| h.is_some()) {
            return hit.iter().map(|h| h.unwrap()).max();
        }
    }
    None
}

/// Second-largest eigenvalue modulus of the (symmetric) weight matrix.
pub fn slem(wm: &WeightMatrix) -> f64 {
    let (vals, _) = sym_eig(&wm.w);
    // vals sorted descending; λ_1 = 1. SLEM = max(|λ_2|, |λ_N|).
    let n = vals.len();
    if n < 2 {
        return 0.0;
    }
    vals[1].abs().max(vals[n - 1].abs())
}

/// Asymptotic per-round error contraction factor (= SLEM); the number of
/// rounds for a factor-δ error reduction is ≈ log(1/δ)/log(1/SLEM).
pub fn rounds_for_accuracy(wm: &WeightMatrix, delta: f64) -> usize {
    let s = slem(wm);
    if s <= 0.0 {
        return 1;
    }
    if s >= 1.0 {
        return usize::MAX;
    }
    ((1.0 / delta).ln() / (1.0 / s).ln()).ceil() as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consensus::weights::local_degree_weights;
    use crate::graph::Graph;
    use crate::util::rng::Rng;

    #[test]
    fn complete_graph_mixes_fast() {
        let g = Graph::complete(10);
        let wm = local_degree_weights(&g);
        let t = mixing_time(&wm, 100).unwrap();
        assert!(t <= 3, "t={t}");
    }

    #[test]
    fn denser_graphs_mix_faster() {
        let mut rng = Rng::new(1);
        let g_dense = Graph::erdos_renyi(20, 0.5, &mut rng);
        let g_sparse = Graph::erdos_renyi(20, 0.1, &mut rng);
        let t_dense = mixing_time(&local_degree_weights(&g_dense), 2000).unwrap();
        let t_sparse = mixing_time(&local_degree_weights(&g_sparse), 2000).unwrap();
        assert!(t_dense <= t_sparse, "dense={t_dense} sparse={t_sparse}");
    }

    #[test]
    fn star_mixing_finite() {
        let g = Graph::star(20);
        let wm = local_degree_weights(&g);
        assert!(mixing_time(&wm, 5000).is_some());
    }

    #[test]
    fn ring_mixes_slowly() {
        // The eq.-(5) threshold (1/2 in ℓ2) is a coarse statistic — even a
        // ring crosses it within a few hops — so the discriminative measure
        // is the SLEM-driven round count for a *tight* accuracy target.
        // Local-degree ring has self-weight 1/3 (aperiodic) so it mixes,
        // but needs far more rounds than an ER graph of the same size.
        let ring = local_degree_weights(&Graph::ring(20));
        let mut rng = Rng::new(2);
        let er = local_degree_weights(&Graph::erdos_renyi(20, 0.25, &mut rng));
        let r_ring = rounds_for_accuracy(&ring, 1e-6);
        let r_er = rounds_for_accuracy(&er, 1e-6);
        assert!(r_ring > r_er, "ring={r_ring} er={r_er}");
        // And the eq.-(5) time is still finite (aperiodic chain).
        assert!(mixing_time(&ring, 20_000).is_some());
    }

    #[test]
    fn slem_below_one_for_connected() {
        let mut rng = Rng::new(3);
        let g = Graph::erdos_renyi(12, 0.4, &mut rng);
        let s = slem(&local_degree_weights(&g));
        assert!(s < 1.0 && s > 0.0, "slem={s}");
    }

    #[test]
    fn slem_ordering_matches_mixing() {
        let ring = slem(&local_degree_weights(&Graph::ring(16)));
        let comp = slem(&local_degree_weights(&Graph::complete(16)));
        assert!(comp < ring);
    }

    #[test]
    fn mixing_time_matches_dense_reference_recurrence() {
        // The sparse per-row application must land on the same eq.-(5)
        // hitting time as the dense P·W recurrence it replaced.
        for g in [Graph::ring(12), Graph::star(12), Graph::complete(9)] {
            let wm = local_degree_weights(&g);
            let n = wm.n();
            let target = 1.0 / n as f64;
            let mut p = Mat::eye(n);
            let mut hit = vec![None; n];
            let mut dense_t = None;
            for t in 1..=5000 {
                p = p.matmul(&wm.w);
                for i in 0..n {
                    if hit[i].is_none() {
                        let dev: f64 = (0..n)
                            .map(|j| (p.get(i, j) - target).powi(2))
                            .sum();
                        if dev.sqrt() <= 0.5 {
                            hit[i] = Some(t);
                        }
                    }
                }
                if hit.iter().all(|h| h.is_some()) {
                    dense_t = hit.iter().map(|h| h.unwrap()).max();
                    break;
                }
            }
            assert_eq!(mixing_time(&wm, 5000), dense_t, "{}", g.kind);
        }
    }

    #[test]
    fn rounds_for_accuracy_monotone_in_delta() {
        let g = Graph::ring(10);
        let wm = local_degree_weights(&g);
        let r1 = rounds_for_accuracy(&wm, 1e-2);
        let r2 = rounds_for_accuracy(&wm, 1e-6);
        assert!(r2 > r1);
    }
}
