//! Doubly-stochastic consensus weight matrices.
//!
//! The paper designs `W` with the **local-degree** method of Xiao & Boyd
//! [16] (a.k.a. Metropolis–Hastings weights):
//!
//! ```text
//! w_ij = 1 / (1 + max(d_i, d_j))   for (i,j) ∈ E
//! w_ii = 1 - Σ_{j∈N(i)} w_ij
//! ```
//!
//! which is symmetric, doubly stochastic, and has positive diagonal —
//! guaranteeing convergence of `W^t → (1/N)·11ᵀ` on connected, non-bipartite
//! effective chains.

use crate::graph::Graph;
use crate::linalg::Mat;

/// A consensus weight matrix tied to a graph (dense `N×N`; `N ≤` a few
/// hundred in all paper experiments, so dense storage is the right call —
/// but the engine only ever applies rows over `N_i`, never the full dense
/// product).
#[derive(Clone, Debug)]
pub struct WeightMatrix {
    pub w: Mat,
}

/// Local-degree (Metropolis–Hastings) weights — the paper's choice.
pub fn local_degree_weights(g: &Graph) -> WeightMatrix {
    let n = g.n;
    let mut w = Mat::zeros(n, n);
    for i in 0..n {
        let mut diag = 1.0;
        for &j in &g.adj[i] {
            let wij = 1.0 / (1.0 + g.degree(i).max(g.degree(j)) as f64);
            w.set(i, j, wij);
            diag -= wij;
        }
        w.set(i, i, diag);
    }
    WeightMatrix { w }
}

/// Metropolis–Hastings weights on the **alive-induced subgraph** — the
/// re-normalization a membership change (node churn, partitions healing)
/// triggers. Degrees are recomputed over surviving neighbors, so the
/// matrix stays symmetric and doubly stochastic on the survivors; a dead
/// node gets the identity row (`w_ii = 1`, no coupling), which keeps
/// shapes stable across epochs. With everyone alive this is **bitwise
/// identical** to [`local_degree_weights`] (same per-row arithmetic
/// order), so the no-fault path is unchanged.
pub fn active_local_degree_weights(g: &Graph, alive: &[bool]) -> WeightMatrix {
    assert_eq!(alive.len(), g.n);
    let n = g.n;
    let mut deg = vec![0usize; n];
    for i in 0..n {
        if alive[i] {
            deg[i] = g.adj[i].iter().filter(|&&j| alive[j]).count();
        }
    }
    let mut w = Mat::zeros(n, n);
    for i in 0..n {
        if !alive[i] {
            w.set(i, i, 1.0);
            continue;
        }
        let mut diag = 1.0;
        for &j in &g.adj[i] {
            if !alive[j] {
                continue;
            }
            let wij = 1.0 / (1.0 + deg[i].max(deg[j]) as f64);
            w.set(i, j, wij);
            diag -= wij;
        }
        w.set(i, i, diag);
    }
    WeightMatrix { w }
}

/// Spectral gap `1 − λ₂` of `W` restricted to the alive subset, where
/// `λ₂` is the modulus of the second-largest eigenvalue — estimated by
/// power iteration on the consensus-deflated operator
/// `W_S − (1/|S|)·11ᵀ`. Positive iff consensus mixes on the survivors.
pub fn active_spectral_gap(wm: &WeightMatrix, alive: &[bool]) -> f64 {
    let idx: Vec<usize> = (0..wm.n()).filter(|&i| alive[i]).collect();
    let s = idx.len();
    if s <= 1 {
        return 1.0;
    }
    let mut b = Mat::zeros(s, s);
    for (a, &i) in idx.iter().enumerate() {
        for (c, &j) in idx.iter().enumerate() {
            b.set(a, c, wm.w.get(i, j) - 1.0 / s as f64);
        }
    }
    1.0 - b.spectral_norm(300)
}

/// Max-degree weights: `w_ij = 1/(1+Δ)` for edges, uniform alternative.
pub fn max_degree_weights(g: &Graph) -> WeightMatrix {
    let n = g.n;
    let dmax = g.max_degree() as f64;
    let mut w = Mat::zeros(n, n);
    for i in 0..n {
        let mut diag = 1.0;
        for &j in &g.adj[i] {
            let wij = 1.0 / (1.0 + dmax);
            w.set(i, j, wij);
            diag -= wij;
        }
        w.set(i, i, diag);
    }
    WeightMatrix { w }
}

impl WeightMatrix {
    pub fn n(&self) -> usize {
        self.w.rows
    }

    /// Row-stochastic check error: `max_i |Σ_j w_ij − 1|`.
    pub fn row_sum_err(&self) -> f64 {
        let n = self.n();
        let mut err = 0.0f64;
        for i in 0..n {
            let s: f64 = self.w.row(i).iter().sum();
            err = err.max((s - 1.0).abs());
        }
        err
    }

    /// Symmetry error (doubly-stochastic follows from symmetry + rows).
    pub fn symmetry_err(&self) -> f64 {
        let n = self.n();
        let mut err = 0.0f64;
        for i in 0..n {
            for j in 0..n {
                err = err.max((self.w.get(i, j) - self.w.get(j, i)).abs());
            }
        }
        err
    }

    /// All entries non-negative?
    pub fn nonnegative(&self) -> bool {
        self.w.data.iter().all(|&v| v >= -1e-15)
    }

    /// `W^t e_1` — the rescaling vector of Alg. 1 step 11. Node `i` divides
    /// its consensus result by entry `i` of this vector to turn the (inexact)
    /// average into a sum estimate.
    pub fn pow_e1(&self, t: usize) -> Vec<f64> {
        let n = self.n();
        let mut v = vec![0.0; n];
        v[0] = 1.0;
        for _ in 0..t {
            let mut nv = vec![0.0; n];
            for i in 0..n {
                let row = self.w.row(i);
                let mut s = 0.0;
                for (wv, xv) in row.iter().zip(v.iter()) {
                    s += wv * xv;
                }
                nv[i] = s;
            }
            v = nv;
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn local_degree_doubly_stochastic() {
        let mut rng = Rng::new(1);
        for spec in ["erdos", "ring", "star"] {
            let g = Graph::from_spec(spec, 12, 0.4, &mut rng);
            let wm = local_degree_weights(&g);
            assert!(wm.row_sum_err() < 1e-12, "{spec}");
            assert!(wm.symmetry_err() < 1e-12, "{spec}");
            assert!(wm.nonnegative(), "{spec}");
        }
    }

    #[test]
    fn max_degree_doubly_stochastic() {
        let mut rng = Rng::new(2);
        let g = Graph::erdos_renyi(15, 0.3, &mut rng);
        let wm = max_degree_weights(&g);
        assert!(wm.row_sum_err() < 1e-12);
        assert!(wm.symmetry_err() < 1e-12);
        assert!(wm.nonnegative());
    }

    #[test]
    fn sparsity_respects_graph() {
        let g = Graph::ring(8);
        let wm = local_degree_weights(&g);
        for i in 0..8 {
            for j in 0..8 {
                if i != j && !g.adj[i].contains(&j) {
                    assert_eq!(wm.w.get(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn ring_weights_value() {
        // Ring: all degrees 2 => w_ij = 1/3 on edges, w_ii = 1/3.
        let g = Graph::ring(6);
        let wm = local_degree_weights(&g);
        assert!((wm.w.get(0, 1) - 1.0 / 3.0).abs() < 1e-12);
        assert!((wm.w.get(0, 0) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn star_weights_value() {
        // Star N=5: hub degree 4, leaves degree 1 => edge weight 1/5.
        let g = Graph::star(5);
        let wm = local_degree_weights(&g);
        assert!((wm.w.get(0, 1) - 0.2).abs() < 1e-12);
        assert!((wm.w.get(0, 0) - (1.0 - 4.0 * 0.2)).abs() < 1e-12);
        assert!((wm.w.get(1, 1) - 0.8).abs() < 1e-12);
    }

    #[test]
    fn active_weights_all_alive_bitwise_matches_plain() {
        let mut rng = Rng::new(9);
        for spec in ["erdos", "ring", "star", "path"] {
            let g = Graph::from_spec(spec, 11, 0.4, &mut rng);
            let plain = local_degree_weights(&g);
            let active = active_local_degree_weights(&g, &vec![true; g.n]);
            for (a, b) in plain.w.data.iter().zip(&active.w.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "{spec}: no-fault path must not drift");
            }
        }
    }

    #[test]
    fn dead_node_gets_identity_row_and_no_coupling() {
        let g = Graph::ring(6);
        let mut alive = vec![true; 6];
        alive[2] = false;
        let wm = active_local_degree_weights(&g, &alive);
        assert_eq!(wm.w.get(2, 2), 1.0);
        for j in 0..6 {
            if j != 2 {
                assert_eq!(wm.w.get(2, j), 0.0);
                assert_eq!(wm.w.get(j, 2), 0.0);
            }
        }
        // Survivors still form a doubly stochastic matrix.
        assert!(wm.row_sum_err() < 1e-12);
        assert!(wm.symmetry_err() < 1e-12);
        assert!(wm.nonnegative());
    }

    /// Satellite property test: after **any** sequence of drop/rejoin
    /// events, the active-subgraph Metropolis–Hastings matrix stays
    /// symmetric, doubly stochastic, and — whenever the surviving graph
    /// is connected — spectral-gap-positive. Churn sequences are drawn
    /// from seeded random masks over several topologies.
    #[test]
    fn active_weights_property_under_random_churn() {
        let mut rng = Rng::new(77);
        for spec in ["erdos", "ring", "star", "grid", "complete"] {
            let g = Graph::from_spec(spec, 12, 0.35, &mut rng);
            let mut alive = vec![true; g.n];
            let mut connected_cases = 0;
            for step in 0..60 {
                // Random drop-or-rejoin event each step (always keep >= 1 up).
                let node = rng.next_below(g.n);
                if alive[node] && alive.iter().filter(|&&a| a).count() > 1 {
                    alive[node] = false;
                } else {
                    alive[node] = true;
                }
                let wm = active_local_degree_weights(&g, &alive);
                assert!(wm.row_sum_err() < 1e-12, "{spec} step {step}");
                assert!(wm.symmetry_err() < 1e-12, "{spec} step {step}");
                assert!(wm.nonnegative(), "{spec} step {step}");
                if g.is_connected_over(&alive) && alive.iter().filter(|&&a| a).count() >= 2 {
                    let gap = active_spectral_gap(&wm, &alive);
                    assert!(gap > 1e-6, "{spec} step {step}: gap={gap}");
                    connected_cases += 1;
                }
            }
            assert!(connected_cases > 0, "{spec}: churn never left a connected survivor set");
        }
    }

    #[test]
    fn disconnected_survivors_have_no_gap() {
        // Path 0-1-2-3-4 with node 2 dead splits in two components:
        // W_S has two stationary vectors, so λ₂ = 1 and the gap is ~0.
        let g = Graph::path(5);
        let mut alive = vec![true; 5];
        alive[2] = false;
        let wm = active_local_degree_weights(&g, &alive);
        assert!(!g.is_connected_over(&alive));
        let gap = active_spectral_gap(&wm, &alive);
        assert!(gap.abs() < 1e-9, "gap={gap}");
    }

    #[test]
    fn pow_e1_converges_to_uniform() {
        let mut rng = Rng::new(3);
        let g = Graph::erdos_renyi(10, 0.5, &mut rng);
        let wm = local_degree_weights(&g);
        let v = wm.pow_e1(200);
        for x in v {
            assert!((x - 0.1).abs() < 1e-8, "x={x}");
        }
    }

    #[test]
    fn pow_e1_zero_steps_is_e1() {
        let g = Graph::ring(5);
        let wm = local_degree_weights(&g);
        let v = wm.pow_e1(0);
        assert_eq!(v[0], 1.0);
        assert!(v[1..].iter().all(|&x| x == 0.0));
    }
}
