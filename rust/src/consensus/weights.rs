//! Doubly-stochastic consensus weight matrices.
//!
//! The paper designs `W` with the **local-degree** method of Xiao & Boyd
//! [16] (a.k.a. Metropolis–Hastings weights):
//!
//! ```text
//! w_ij = 1 / (1 + max(d_i, d_j))   for (i,j) ∈ E
//! w_ii = 1 - Σ_{j∈N(i)} w_ij
//! ```
//!
//! which is symmetric, doubly stochastic, and has positive diagonal —
//! guaranteeing convergence of `W^t → (1/N)·11ᵀ` on connected, non-bipartite
//! effective chains.
//!
//! Two representations share that arithmetic:
//!
//! * [`WeightMatrix`] — the dense `N×N` reference, fine for paper-sized
//!   N ≤ ~20 and kept as the oracle the sparse path is parity-tested
//!   against.
//! * [`SparseWeights`] — CSR-style per-node `(neighbor, weight)` lists
//!   built straight off `Graph::adj`, the production representation: a
//!   consensus round over it costs O(edges), and at N = 10⁴ it stores
//!   ~2|E| values instead of 10⁸. Because Metropolis weights are pure
//!   functions of degrees and both builders subtract edge weights from
//!   the diagonal in the same adjacency order, sparse and dense mixing
//!   are **bitwise identical** (pinned by tests here and in
//!   `consensus::engine`).

use crate::graph::Graph;
use crate::linalg::Mat;

/// A consensus weight matrix tied to a graph (dense `N×N`; `N ≤` a few
/// hundred in all paper experiments, so dense storage is the right call —
/// but the engine only ever applies rows over `N_i`, never the full dense
/// product).
#[derive(Clone, Debug)]
pub struct WeightMatrix {
    pub w: Mat,
}

/// Local-degree (Metropolis–Hastings) weights — the paper's choice.
pub fn local_degree_weights(g: &Graph) -> WeightMatrix {
    let n = g.n;
    let mut w = Mat::zeros(n, n);
    for i in 0..n {
        let mut diag = 1.0;
        for &j in &g.adj[i] {
            let wij = 1.0 / (1.0 + g.degree(i).max(g.degree(j)) as f64);
            w.set(i, j, wij);
            diag -= wij;
        }
        w.set(i, i, diag);
    }
    WeightMatrix { w }
}

/// Metropolis–Hastings weights on the **alive-induced subgraph** — the
/// re-normalization a membership change (node churn, partitions healing)
/// triggers. Degrees are recomputed over surviving neighbors, so the
/// matrix stays symmetric and doubly stochastic on the survivors; a dead
/// node gets the identity row (`w_ii = 1`, no coupling), which keeps
/// shapes stable across epochs. With everyone alive this is **bitwise
/// identical** to [`local_degree_weights`] (same per-row arithmetic
/// order), so the no-fault path is unchanged.
pub fn active_local_degree_weights(g: &Graph, alive: &[bool]) -> WeightMatrix {
    assert_eq!(alive.len(), g.n);
    let n = g.n;
    let mut deg = vec![0usize; n];
    for i in 0..n {
        if alive[i] {
            deg[i] = g.adj[i].iter().filter(|&&j| alive[j]).count();
        }
    }
    let mut w = Mat::zeros(n, n);
    for i in 0..n {
        if !alive[i] {
            w.set(i, i, 1.0);
            continue;
        }
        let mut diag = 1.0;
        for &j in &g.adj[i] {
            if !alive[j] {
                continue;
            }
            let wij = 1.0 / (1.0 + deg[i].max(deg[j]) as f64);
            w.set(i, j, wij);
            diag -= wij;
        }
        w.set(i, i, diag);
    }
    WeightMatrix { w }
}

/// Spectral gap `1 − λ₂` of `W` restricted to the alive subset, where
/// `λ₂` is the modulus of the second-largest eigenvalue — estimated by
/// power iteration on the consensus-deflated operator
/// `W_S − (1/|S|)·11ᵀ`. Positive iff consensus mixes on the survivors.
///
/// Dense reference path: materializes the |S|×|S| deflated operator, so
/// it is quadratic in survivors — use [`sparse_active_spectral_gap`] for
/// anything beyond paper-sized N (it dispatches back here below
/// [`DENSE_GAP_NODES`], bitwise identically).
pub fn active_spectral_gap(wm: &WeightMatrix, alive: &[bool]) -> f64 {
    let idx: Vec<usize> = (0..wm.n()).filter(|&i| alive[i]).collect();
    let s = idx.len();
    if s <= 1 {
        return 1.0;
    }
    let mut b = Mat::zeros(s, s);
    for (a, &i) in idx.iter().enumerate() {
        for (c, &j) in idx.iter().enumerate() {
            b.set(a, c, wm.w.get(i, j) - 1.0 / s as f64);
        }
    }
    1.0 - b.spectral_norm(300)
}

/// Survivor-count threshold below which [`sparse_active_spectral_gap`]
/// materializes the compact dense operator (bitwise equal to
/// [`active_spectral_gap`]); above it the matrix-free estimate runs in
/// O(iters · (edges + N)).
pub const DENSE_GAP_NODES: usize = 128;

/// Spectral gap `1 − λ₂` on the alive subset from **sparse** active
/// weights (`sw` as produced by [`SparseWeights::refresh_active`]).
///
/// Below [`DENSE_GAP_NODES`] survivors this compacts the deflated
/// operator `B = W_S − (1/|S|)·11ᵀ` into a dense matrix and reuses the
/// reference power iteration — bitwise identical to
/// [`active_spectral_gap`] on the matching dense matrix. Above the
/// threshold it runs the same fixed-iteration (300-step) power scheme on
/// `B²` matrix-free: the uniform start is annihilated by `B` up to the
/// row-sum rounding residue, whose generic overlap with the λ₂
/// eigenspace seeds the iteration — deterministic, same mechanism as the
/// dense path, but with a different summation order, so large-N parity
/// with the dense estimate is tolerance-level rather than bitwise.
pub fn sparse_active_spectral_gap(sw: &SparseWeights, alive: &[bool]) -> f64 {
    let n = sw.n();
    assert_eq!(alive.len(), n);
    let s = alive.iter().filter(|&&a| a).count();
    if s <= 1 {
        return 1.0;
    }
    let inv = 1.0 / s as f64;
    if s <= DENSE_GAP_NODES {
        // Compact position map: node id -> survivor index.
        let mut pos = vec![usize::MAX; n];
        let mut a = 0usize;
        for (i, p) in pos.iter_mut().enumerate() {
            if alive[i] {
                *p = a;
                a += 1;
            }
        }
        let mut b = Mat::zeros(s, s);
        for i in 0..n {
            if !alive[i] {
                continue;
            }
            let r = pos[i];
            // `0.0 - inv == -inv` bitwise, so pre-filling the row and
            // overwriting the structural entries reproduces the dense
            // `w.get(i, j) - inv` construction bit-for-bit.
            for c in 0..s {
                b.set(r, c, -inv);
            }
            let (cols, vals) = sw.row(i);
            for (&j, &v) in cols.iter().zip(vals.iter()) {
                if alive[j] {
                    b.set(r, pos[j], v - inv);
                }
            }
            b.set(r, r, sw.diag[i] - inv);
        }
        return 1.0 - b.spectral_norm(300);
    }
    // Matrix-free power iteration on B² over full-length masked vectors.
    let mut v = vec![0.0; n];
    let seed = 1.0 / (s as f64).sqrt();
    for (i, x) in v.iter_mut().enumerate() {
        if alive[i] {
            *x = seed;
        }
    }
    let mut bv = vec![0.0; n];
    let mut w = vec![0.0; n];
    let mut norm = 0.0;
    for _ in 0..300 {
        apply_deflated(sw, alive, inv, &v, &mut bv);
        apply_deflated(sw, alive, inv, &bv, &mut w);
        let wn = w.iter().map(|x| x * x).sum::<f64>().sqrt();
        if wn == 0.0 {
            // B ≡ 0 on the survivors (exact-arithmetic complete graph):
            // λ₂ = 0, maximal gap — mirrors `Mat::spectral_norm`'s zero
            // return feeding `1.0 - 0.0` on the dense path.
            return 1.0;
        }
        for x in w.iter_mut() {
            *x /= wn;
        }
        std::mem::swap(&mut v, &mut w);
        norm = wn;
    }
    1.0 - norm.sqrt()
}

/// `out = (W_S − (1/|S|)·11ᵀ) v` on the alive coordinates (dead
/// coordinates of `v` are zero and stay zero in `out`).
fn apply_deflated(sw: &SparseWeights, alive: &[bool], inv: f64, v: &[f64], out: &mut [f64]) {
    let mut sum = 0.0;
    for (i, &x) in v.iter().enumerate() {
        if alive[i] {
            sum += x;
        }
    }
    let shift = inv * sum;
    for (i, o) in out.iter_mut().enumerate() {
        if !alive[i] {
            *o = 0.0;
            continue;
        }
        let (cols, vals) = sw.row(i);
        let mut acc = sw.diag[i] * v[i];
        for (&j, &wv) in cols.iter().zip(vals.iter()) {
            acc += wv * v[j];
        }
        *o = acc - shift;
    }
}

/// Max-degree weights: `w_ij = 1/(1+Δ)` for edges, uniform alternative.
pub fn max_degree_weights(g: &Graph) -> WeightMatrix {
    let n = g.n;
    let dmax = g.max_degree() as f64;
    let mut w = Mat::zeros(n, n);
    for i in 0..n {
        let mut diag = 1.0;
        for &j in &g.adj[i] {
            let wij = 1.0 / (1.0 + dmax);
            w.set(i, j, wij);
            diag -= wij;
        }
        w.set(i, i, diag);
    }
    WeightMatrix { w }
}

impl WeightMatrix {
    pub fn n(&self) -> usize {
        self.w.rows
    }

    /// Row-stochastic check error: `max_i |Σ_j w_ij − 1|`.
    pub fn row_sum_err(&self) -> f64 {
        let n = self.n();
        let mut err = 0.0f64;
        for i in 0..n {
            let s: f64 = self.w.row(i).iter().sum();
            err = err.max((s - 1.0).abs());
        }
        err
    }

    /// Symmetry error (doubly-stochastic follows from symmetry + rows).
    pub fn symmetry_err(&self) -> f64 {
        let n = self.n();
        let mut err = 0.0f64;
        for i in 0..n {
            for j in 0..n {
                err = err.max((self.w.get(i, j) - self.w.get(j, i)).abs());
            }
        }
        err
    }

    /// All entries non-negative?
    pub fn nonnegative(&self) -> bool {
        self.w.data.iter().all(|&v| v >= -1e-15)
    }

    /// `W^t e_1` — the rescaling vector of Alg. 1 step 11. Node `i` divides
    /// its consensus result by entry `i` of this vector to turn the (inexact)
    /// average into a sum estimate.
    pub fn pow_e1(&self, t: usize) -> Vec<f64> {
        let n = self.n();
        let mut v = vec![0.0; n];
        v[0] = 1.0;
        for _ in 0..t {
            let mut nv = vec![0.0; n];
            for i in 0..n {
                let row = self.w.row(i);
                let mut s = 0.0;
                for (wv, xv) in row.iter().zip(v.iter()) {
                    s += wv * xv;
                }
                nv[i] = s;
            }
            v = nv;
        }
        v
    }
}

/// CSR-style sparse consensus weights: per-node neighbor/weight lists in
/// `Graph::adj` order plus a separate diagonal.
///
/// Invariants (all builders maintain them):
/// * `off.len() == n + 1`, row `i` occupies `cols[off[i]..off[i+1]]` /
///   `vals[off[i]..off[i+1]]`, mirroring `g.adj[i]` element-for-element
///   (adjacency lists are sorted ascending by construction in
///   `graph::Graph`).
/// * A structurally present entry may hold `0.0` (a dead neighbor after
///   [`SparseWeights::refresh_active`]); kernels that must match the
///   dense *faulty* path bitwise skip dead neighbors via the alive mask
///   instead of multiplying the stored zero through (`d + 0.0·s` is not
///   a bitwise no-op when `d == -0.0`).
#[derive(Clone, Debug, Default)]
pub struct SparseWeights {
    /// Row offsets; `off[i]..off[i+1]` is row `i`'s neighbor range.
    pub off: Vec<usize>,
    /// Neighbor ids, in adjacency (ascending) order.
    pub cols: Vec<usize>,
    /// Off-diagonal weights, aligned with `cols`.
    pub vals: Vec<f64>,
    /// Diagonal weights `w_ii`.
    pub diag: Vec<f64>,
    /// Alive-degree scratch reused across membership epochs.
    deg: Vec<usize>,
}

impl SparseWeights {
    /// Structure-only skeleton mirroring `g.adj` (all weights zero).
    pub fn with_structure(g: &Graph) -> SparseWeights {
        let n = g.n;
        let mut off = Vec::with_capacity(n + 1);
        off.push(0usize);
        let mut cols = Vec::new();
        for i in 0..n {
            cols.extend_from_slice(&g.adj[i]);
            off.push(cols.len());
        }
        let nnz = cols.len();
        SparseWeights { off, cols, vals: vec![0.0; nnz], diag: vec![0.0; n], deg: Vec::new() }
    }

    /// Extract the graph-structured entries of a dense matrix (custom
    /// weight designs enter the sparse engine here; the consensus kernels
    /// only ever read adjacency entries plus the diagonal, so this loses
    /// nothing for any `W` respecting the graph's sparsity pattern).
    pub fn from_dense(g: &Graph, wm: &WeightMatrix) -> SparseWeights {
        assert_eq!(wm.n(), g.n, "weight matrix shape must match the graph");
        let mut sw = SparseWeights::with_structure(g);
        for i in 0..g.n {
            let lo = sw.off[i];
            for (k, &j) in g.adj[i].iter().enumerate() {
                sw.vals[lo + k] = wm.w.get(i, j);
            }
            sw.diag[i] = wm.w.get(i, i);
        }
        sw
    }

    /// Recover the sparse form from a dense matrix alone by scanning for
    /// structural nonzeros (for call sites that hold only a
    /// `WeightMatrix`, e.g. mixing diagnostics). Rows stay in ascending
    /// column order, so the kernels' bitwise contracts hold whenever the
    /// dense matrix respects some graph's sparsity pattern.
    pub fn from_matrix(wm: &WeightMatrix) -> SparseWeights {
        let n = wm.n();
        let mut off = Vec::with_capacity(n + 1);
        off.push(0usize);
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        let mut diag = vec![0.0; n];
        for i in 0..n {
            for j in 0..n {
                let v = wm.w.get(i, j);
                if j == i {
                    diag[i] = v;
                } else if v != 0.0 {
                    cols.push(j);
                    vals.push(v);
                }
            }
            off.push(cols.len());
        }
        SparseWeights { off, cols, vals, diag, deg: Vec::new() }
    }

    pub fn n(&self) -> usize {
        self.diag.len()
    }

    /// Stored off-diagonal entry count (2|E|).
    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    /// Row `i`'s `(neighbor ids, weights)` slices.
    #[inline]
    pub fn row(&self, i: usize) -> (&[usize], &[f64]) {
        let (lo, hi) = (self.off[i], self.off[i + 1]);
        (&self.cols[lo..hi], &self.vals[lo..hi])
    }

    /// Recompute Metropolis–Hastings weights on the alive-induced
    /// subgraph **in place** — the membership-epoch path: O(active edges)
    /// instead of an N×N rebuild, and buffer-reusing after the first
    /// call. Value-for-value (hence bitwise, via [`Self::to_dense`])
    /// identical to [`active_local_degree_weights`]: same degree
    /// recomputation, same per-row subtraction order. Dead rows get the
    /// identity row (`diag = 1`); entries toward dead neighbors are
    /// zeroed but remain structurally present.
    pub fn refresh_active(&mut self, g: &Graph, alive: &[bool]) {
        assert_eq!(alive.len(), g.n);
        assert_eq!(self.n(), g.n, "sparse structure must match the graph");
        let SparseWeights { off, cols, vals, diag, deg } = self;
        deg.clear();
        deg.resize(g.n, 0);
        for i in 0..g.n {
            if alive[i] {
                deg[i] = g.adj[i].iter().filter(|&&j| alive[j]).count();
            }
        }
        for i in 0..g.n {
            let (lo, hi) = (off[i], off[i + 1]);
            if !alive[i] {
                for v in &mut vals[lo..hi] {
                    *v = 0.0;
                }
                diag[i] = 1.0;
                continue;
            }
            let mut d = 1.0;
            for k in lo..hi {
                let j = cols[k];
                if !alive[j] {
                    vals[k] = 0.0;
                    continue;
                }
                let wij = 1.0 / (1.0 + deg[i].max(deg[j]) as f64);
                vals[k] = wij;
                d -= wij;
            }
            diag[i] = d;
        }
    }

    /// Materialize the dense reference (tests and small-N diagnostics).
    pub fn to_dense(&self) -> WeightMatrix {
        let n = self.n();
        let mut w = Mat::zeros(n, n);
        for i in 0..n {
            let (cols, vals) = self.row(i);
            for (&j, &v) in cols.iter().zip(vals.iter()) {
                w.set(i, j, v);
            }
            w.set(i, i, self.diag[i]);
        }
        WeightMatrix { w }
    }

    /// Sparse `W^t e_1` — **bitwise identical** to
    /// [`WeightMatrix::pow_e1`] on the matching dense matrix. The dense
    /// row dot accumulates over all `j` ascending; every structural zero
    /// contributes an exact `±0.0` term, and the running sum is never
    /// `-0.0` (it starts at `+0.0`, and `+0.0 + ±0.0 = +0.0` while exact
    /// cancellation rounds to `+0.0`), so adding those terms is a bitwise
    /// no-op. Skipping them and interleaving the diagonal at column `i`
    /// therefore reproduces the dense bits while costing O(edges) per
    /// step.
    pub fn pow_e1(&self, t: usize) -> Vec<f64> {
        let n = self.n();
        let mut v = vec![0.0; n];
        if n > 0 {
            v[0] = 1.0;
        }
        let mut nv = vec![0.0; n];
        for _ in 0..t {
            self.apply(&v, &mut nv);
            std::mem::swap(&mut v, &mut nv);
        }
        v
    }

    /// One application `dst = W · src` in O(nnz), with the interleaved
    /// accumulation order that reproduces the dense row dot bitwise (see
    /// [`Self::pow_e1`] for the zero-skip argument).
    pub fn apply(&self, src: &[f64], dst: &mut [f64]) {
        let n = self.n();
        debug_assert_eq!(src.len(), n);
        debug_assert_eq!(dst.len(), n);
        for i in 0..n {
            let (lo, hi) = (self.off[i], self.off[i + 1]);
            let mut s = 0.0;
            let mut k = lo;
            while k < hi && self.cols[k] < i {
                s += self.vals[k] * src[self.cols[k]];
                k += 1;
            }
            s += self.diag[i] * src[i];
            while k < hi {
                s += self.vals[k] * src[self.cols[k]];
                k += 1;
            }
            dst[i] = s;
        }
    }
}

/// Local-degree (Metropolis–Hastings) weights in sparse form — the same
/// per-row arithmetic order as [`local_degree_weights`], so
/// `sparse_local_degree_weights(g).to_dense()` is bitwise identical to
/// the dense builder.
pub fn sparse_local_degree_weights(g: &Graph) -> SparseWeights {
    let mut sw = SparseWeights::with_structure(g);
    for i in 0..g.n {
        let lo = sw.off[i];
        let mut diag = 1.0;
        for (k, &j) in g.adj[i].iter().enumerate() {
            let wij = 1.0 / (1.0 + g.degree(i).max(g.degree(j)) as f64);
            sw.vals[lo + k] = wij;
            diag -= wij;
        }
        sw.diag[i] = diag;
    }
    sw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn local_degree_doubly_stochastic() {
        let mut rng = Rng::new(1);
        for spec in ["erdos", "ring", "star"] {
            let g = Graph::from_spec(spec, 12, 0.4, &mut rng);
            let wm = local_degree_weights(&g);
            assert!(wm.row_sum_err() < 1e-12, "{spec}");
            assert!(wm.symmetry_err() < 1e-12, "{spec}");
            assert!(wm.nonnegative(), "{spec}");
        }
    }

    #[test]
    fn max_degree_doubly_stochastic() {
        let mut rng = Rng::new(2);
        let g = Graph::erdos_renyi(15, 0.3, &mut rng);
        let wm = max_degree_weights(&g);
        assert!(wm.row_sum_err() < 1e-12);
        assert!(wm.symmetry_err() < 1e-12);
        assert!(wm.nonnegative());
    }

    #[test]
    fn sparsity_respects_graph() {
        let g = Graph::ring(8);
        let wm = local_degree_weights(&g);
        for i in 0..8 {
            for j in 0..8 {
                if i != j && !g.adj[i].contains(&j) {
                    assert_eq!(wm.w.get(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn ring_weights_value() {
        // Ring: all degrees 2 => w_ij = 1/3 on edges, w_ii = 1/3.
        let g = Graph::ring(6);
        let wm = local_degree_weights(&g);
        assert!((wm.w.get(0, 1) - 1.0 / 3.0).abs() < 1e-12);
        assert!((wm.w.get(0, 0) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn star_weights_value() {
        // Star N=5: hub degree 4, leaves degree 1 => edge weight 1/5.
        let g = Graph::star(5);
        let wm = local_degree_weights(&g);
        assert!((wm.w.get(0, 1) - 0.2).abs() < 1e-12);
        assert!((wm.w.get(0, 0) - (1.0 - 4.0 * 0.2)).abs() < 1e-12);
        assert!((wm.w.get(1, 1) - 0.8).abs() < 1e-12);
    }

    #[test]
    fn active_weights_all_alive_bitwise_matches_plain() {
        let mut rng = Rng::new(9);
        for spec in ["erdos", "ring", "star", "path"] {
            let g = Graph::from_spec(spec, 11, 0.4, &mut rng);
            let plain = local_degree_weights(&g);
            let active = active_local_degree_weights(&g, &vec![true; g.n]);
            for (a, b) in plain.w.data.iter().zip(&active.w.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "{spec}: no-fault path must not drift");
            }
        }
    }

    #[test]
    fn dead_node_gets_identity_row_and_no_coupling() {
        let g = Graph::ring(6);
        let mut alive = vec![true; 6];
        alive[2] = false;
        let wm = active_local_degree_weights(&g, &alive);
        assert_eq!(wm.w.get(2, 2), 1.0);
        for j in 0..6 {
            if j != 2 {
                assert_eq!(wm.w.get(2, j), 0.0);
                assert_eq!(wm.w.get(j, 2), 0.0);
            }
        }
        // Survivors still form a doubly stochastic matrix.
        assert!(wm.row_sum_err() < 1e-12);
        assert!(wm.symmetry_err() < 1e-12);
        assert!(wm.nonnegative());
    }

    /// Satellite property test: after **any** sequence of drop/rejoin
    /// events, the active-subgraph Metropolis–Hastings matrix stays
    /// symmetric, doubly stochastic, and — whenever the surviving graph
    /// is connected — spectral-gap-positive. Churn sequences are drawn
    /// from seeded random masks over several topologies.
    #[test]
    fn active_weights_property_under_random_churn() {
        let mut rng = Rng::new(77);
        for spec in ["erdos", "ring", "star", "grid", "complete"] {
            let g = Graph::from_spec(spec, 12, 0.35, &mut rng);
            let mut alive = vec![true; g.n];
            let mut connected_cases = 0;
            for step in 0..60 {
                // Random drop-or-rejoin event each step (always keep >= 1 up).
                let node = rng.next_below(g.n);
                if alive[node] && alive.iter().filter(|&&a| a).count() > 1 {
                    alive[node] = false;
                } else {
                    alive[node] = true;
                }
                let wm = active_local_degree_weights(&g, &alive);
                assert!(wm.row_sum_err() < 1e-12, "{spec} step {step}");
                assert!(wm.symmetry_err() < 1e-12, "{spec} step {step}");
                assert!(wm.nonnegative(), "{spec} step {step}");
                if g.is_connected_over(&alive) && alive.iter().filter(|&&a| a).count() >= 2 {
                    let gap = active_spectral_gap(&wm, &alive);
                    assert!(gap > 1e-6, "{spec} step {step}: gap={gap}");
                    connected_cases += 1;
                }
            }
            assert!(connected_cases > 0, "{spec}: churn never left a connected survivor set");
        }
    }

    #[test]
    fn disconnected_survivors_have_no_gap() {
        // Path 0-1-2-3-4 with node 2 dead splits in two components:
        // W_S has two stationary vectors, so λ₂ = 1 and the gap is ~0.
        let g = Graph::path(5);
        let mut alive = vec![true; 5];
        alive[2] = false;
        let wm = active_local_degree_weights(&g, &alive);
        assert!(!g.is_connected_over(&alive));
        let gap = active_spectral_gap(&wm, &alive);
        assert!(gap.abs() < 1e-9, "gap={gap}");
    }

    #[test]
    fn pow_e1_converges_to_uniform() {
        let mut rng = Rng::new(3);
        let g = Graph::erdos_renyi(10, 0.5, &mut rng);
        let wm = local_degree_weights(&g);
        let v = wm.pow_e1(200);
        for x in v {
            assert!((x - 0.1).abs() < 1e-8, "x={x}");
        }
    }

    #[test]
    fn pow_e1_zero_steps_is_e1() {
        let g = Graph::ring(5);
        let wm = local_degree_weights(&g);
        let v = wm.pow_e1(0);
        assert_eq!(v[0], 1.0);
        assert!(v[1..].iter().all(|&x| x == 0.0));
    }

    fn assert_bits_eq(a: &WeightMatrix, b: &WeightMatrix, what: &str) {
        assert_eq!(a.n(), b.n(), "{what}: shape");
        for (x, y) in a.w.data.iter().zip(&b.w.data) {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: sparse≡dense bit contract");
        }
    }

    #[test]
    fn sparse_builder_bitwise_matches_dense() {
        let mut rng = Rng::new(21);
        for spec in ["erdos", "ring", "star", "path", "grid", "complete"] {
            let g = Graph::from_spec(spec, 16, 0.4, &mut rng);
            let dense = local_degree_weights(&g);
            let sparse = sparse_local_degree_weights(&g);
            assert_bits_eq(&sparse.to_dense(), &dense, spec);
            // Round-trip through the dense extractor lands on the same bits.
            let rt = SparseWeights::from_dense(&g, &dense);
            assert_bits_eq(&rt.to_dense(), &dense, spec);
            assert_eq!(sparse.nnz(), g.adj.iter().map(Vec::len).sum::<usize>());
            // The graph-free nonzero scan recovers the same structure.
            let scanned = SparseWeights::from_matrix(&dense);
            assert_eq!(scanned.off, sparse.off, "{spec}");
            assert_eq!(scanned.cols, sparse.cols, "{spec}");
            assert_bits_eq(&scanned.to_dense(), &dense, spec);
        }
    }

    #[test]
    fn sparse_refresh_active_bitwise_matches_dense_active() {
        let mut rng = Rng::new(31);
        for spec in ["erdos", "ring", "star", "grid", "complete"] {
            let g = Graph::from_spec(spec, 12, 0.35, &mut rng);
            let mut sw = sparse_local_degree_weights(&g);
            let mut alive = vec![true; g.n];
            for _step in 0..40 {
                let node = rng.next_below(g.n);
                if alive[node] && alive.iter().filter(|&&a| a).count() > 1 {
                    alive[node] = false;
                } else {
                    alive[node] = true;
                }
                sw.refresh_active(&g, &alive);
                let dense = active_local_degree_weights(&g, &alive);
                assert_bits_eq(&sw.to_dense(), &dense, spec);
            }
        }
    }

    #[test]
    fn sparse_pow_e1_bitwise_matches_dense() {
        let mut rng = Rng::new(41);
        for spec in ["erdos", "ring", "star", "grid"] {
            let g = Graph::from_spec(spec, 13, 0.4, &mut rng);
            let dense = local_degree_weights(&g);
            let sparse = sparse_local_degree_weights(&g);
            for t in [0usize, 1, 7, 53] {
                let dv = dense.pow_e1(t);
                let sv = sparse.pow_e1(t);
                for (a, b) in dv.iter().zip(&sv) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{spec} t={t}");
                }
            }
        }
    }

    #[test]
    fn sparse_gap_small_n_bitwise_matches_dense_reference() {
        let mut rng = Rng::new(51);
        for spec in ["erdos", "ring", "star", "grid"] {
            let g = Graph::from_spec(spec, 12, 0.4, &mut rng);
            let mut sw = sparse_local_degree_weights(&g);
            let mut alive = vec![true; g.n];
            for _step in 0..20 {
                let node = rng.next_below(g.n);
                if alive[node] && alive.iter().filter(|&&a| a).count() > 2 {
                    alive[node] = false;
                } else {
                    alive[node] = true;
                }
                sw.refresh_active(&g, &alive);
                let dense = active_local_degree_weights(&g, &alive);
                let gd = active_spectral_gap(&dense, &alive);
                let gs = sparse_active_spectral_gap(&sw, &alive);
                assert_eq!(gd.to_bits(), gs.to_bits(), "{spec}: sub-threshold gap dispatch");
            }
        }
    }

    #[test]
    fn sparse_gap_parity_with_sym_eig_at_small_n() {
        let mut rng = Rng::new(61);
        for spec in ["erdos", "path", "ring"] {
            let g = Graph::from_spec(spec, 14, 0.45, &mut rng);
            let mut alive = vec![true; g.n];
            alive[3] = false;
            let dense = active_local_degree_weights(&g, &alive);
            let mut sw = sparse_local_degree_weights(&g);
            sw.refresh_active(&g, &alive);
            // Exact λ₂ modulus from the compacted survivor matrix.
            let idx: Vec<usize> = (0..g.n).filter(|&i| alive[i]).collect();
            let s = idx.len();
            let mut ws = Mat::zeros(s, s);
            for (a, &i) in idx.iter().enumerate() {
                for (c, &j) in idx.iter().enumerate() {
                    ws.set(a, c, dense.w.get(i, j));
                }
            }
            let (vals, _) = crate::linalg::eig::sym_eig(&ws);
            let lam2 = vals[1].abs().max(vals[s - 1].abs());
            let gap = sparse_active_spectral_gap(&sw, &alive);
            assert!(
                (gap - (1.0 - lam2)).abs() < 1e-5,
                "{spec}: power estimate {gap} vs sym_eig {}",
                1.0 - lam2
            );
        }
    }

    #[test]
    fn sparse_gap_matrix_free_parity_above_threshold() {
        // 160 survivors > DENSE_GAP_NODES forces the matrix-free path;
        // the dense reference stays feasible at this size, so the two
        // estimates (same 300-iteration scheme, different summation
        // order) must agree to tolerance.
        let mut rng = Rng::new(71);
        let g = Graph::erdos_renyi(160, 0.12, &mut rng);
        let mut alive = vec![true; g.n];
        alive[7] = false;
        alive[93] = false;
        let dense = active_local_degree_weights(&g, &alive);
        let mut sw = sparse_local_degree_weights(&g);
        sw.refresh_active(&g, &alive);
        let gd = active_spectral_gap(&dense, &alive);
        let gs = sparse_active_spectral_gap(&sw, &alive);
        assert!(gs > 1e-6, "expander survivors must keep a gap, got {gs}");
        assert!((gd - gs).abs() < 1e-5, "dense {gd} vs matrix-free {gs}");
    }
}
