//! Doubly-stochastic consensus weight matrices.
//!
//! The paper designs `W` with the **local-degree** method of Xiao & Boyd
//! [16] (a.k.a. Metropolis–Hastings weights):
//!
//! ```text
//! w_ij = 1 / (1 + max(d_i, d_j))   for (i,j) ∈ E
//! w_ii = 1 - Σ_{j∈N(i)} w_ij
//! ```
//!
//! which is symmetric, doubly stochastic, and has positive diagonal —
//! guaranteeing convergence of `W^t → (1/N)·11ᵀ` on connected, non-bipartite
//! effective chains.

use crate::graph::Graph;
use crate::linalg::Mat;

/// A consensus weight matrix tied to a graph (dense `N×N`; `N ≤` a few
/// hundred in all paper experiments, so dense storage is the right call —
/// but the engine only ever applies rows over `N_i`, never the full dense
/// product).
#[derive(Clone, Debug)]
pub struct WeightMatrix {
    pub w: Mat,
}

/// Local-degree (Metropolis–Hastings) weights — the paper's choice.
pub fn local_degree_weights(g: &Graph) -> WeightMatrix {
    let n = g.n;
    let mut w = Mat::zeros(n, n);
    for i in 0..n {
        let mut diag = 1.0;
        for &j in &g.adj[i] {
            let wij = 1.0 / (1.0 + g.degree(i).max(g.degree(j)) as f64);
            w.set(i, j, wij);
            diag -= wij;
        }
        w.set(i, i, diag);
    }
    WeightMatrix { w }
}

/// Max-degree weights: `w_ij = 1/(1+Δ)` for edges, uniform alternative.
pub fn max_degree_weights(g: &Graph) -> WeightMatrix {
    let n = g.n;
    let dmax = g.max_degree() as f64;
    let mut w = Mat::zeros(n, n);
    for i in 0..n {
        let mut diag = 1.0;
        for &j in &g.adj[i] {
            let wij = 1.0 / (1.0 + dmax);
            w.set(i, j, wij);
            diag -= wij;
        }
        w.set(i, i, diag);
    }
    WeightMatrix { w }
}

impl WeightMatrix {
    pub fn n(&self) -> usize {
        self.w.rows
    }

    /// Row-stochastic check error: `max_i |Σ_j w_ij − 1|`.
    pub fn row_sum_err(&self) -> f64 {
        let n = self.n();
        let mut err = 0.0f64;
        for i in 0..n {
            let s: f64 = self.w.row(i).iter().sum();
            err = err.max((s - 1.0).abs());
        }
        err
    }

    /// Symmetry error (doubly-stochastic follows from symmetry + rows).
    pub fn symmetry_err(&self) -> f64 {
        let n = self.n();
        let mut err = 0.0f64;
        for i in 0..n {
            for j in 0..n {
                err = err.max((self.w.get(i, j) - self.w.get(j, i)).abs());
            }
        }
        err
    }

    /// All entries non-negative?
    pub fn nonnegative(&self) -> bool {
        self.w.data.iter().all(|&v| v >= -1e-15)
    }

    /// `W^t e_1` — the rescaling vector of Alg. 1 step 11. Node `i` divides
    /// its consensus result by entry `i` of this vector to turn the (inexact)
    /// average into a sum estimate.
    pub fn pow_e1(&self, t: usize) -> Vec<f64> {
        let n = self.n();
        let mut v = vec![0.0; n];
        v[0] = 1.0;
        for _ in 0..t {
            let mut nv = vec![0.0; n];
            for i in 0..n {
                let row = self.w.row(i);
                let mut s = 0.0;
                for (wv, xv) in row.iter().zip(v.iter()) {
                    s += wv * xv;
                }
                nv[i] = s;
            }
            v = nv;
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn local_degree_doubly_stochastic() {
        let mut rng = Rng::new(1);
        for spec in ["erdos", "ring", "star"] {
            let g = Graph::from_spec(spec, 12, 0.4, &mut rng);
            let wm = local_degree_weights(&g);
            assert!(wm.row_sum_err() < 1e-12, "{spec}");
            assert!(wm.symmetry_err() < 1e-12, "{spec}");
            assert!(wm.nonnegative(), "{spec}");
        }
    }

    #[test]
    fn max_degree_doubly_stochastic() {
        let mut rng = Rng::new(2);
        let g = Graph::erdos_renyi(15, 0.3, &mut rng);
        let wm = max_degree_weights(&g);
        assert!(wm.row_sum_err() < 1e-12);
        assert!(wm.symmetry_err() < 1e-12);
        assert!(wm.nonnegative());
    }

    #[test]
    fn sparsity_respects_graph() {
        let g = Graph::ring(8);
        let wm = local_degree_weights(&g);
        for i in 0..8 {
            for j in 0..8 {
                if i != j && !g.adj[i].contains(&j) {
                    assert_eq!(wm.w.get(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn ring_weights_value() {
        // Ring: all degrees 2 => w_ij = 1/3 on edges, w_ii = 1/3.
        let g = Graph::ring(6);
        let wm = local_degree_weights(&g);
        assert!((wm.w.get(0, 1) - 1.0 / 3.0).abs() < 1e-12);
        assert!((wm.w.get(0, 0) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn star_weights_value() {
        // Star N=5: hub degree 4, leaves degree 1 => edge weight 1/5.
        let g = Graph::star(5);
        let wm = local_degree_weights(&g);
        assert!((wm.w.get(0, 1) - 0.2).abs() < 1e-12);
        assert!((wm.w.get(0, 0) - (1.0 - 4.0 * 0.2)).abs() < 1e-12);
        assert!((wm.w.get(1, 1) - 0.8).abs() < 1e-12);
    }

    #[test]
    fn pow_e1_converges_to_uniform() {
        let mut rng = Rng::new(3);
        let g = Graph::erdos_renyi(10, 0.5, &mut rng);
        let wm = local_degree_weights(&g);
        let v = wm.pow_e1(200);
        for x in v {
            assert!((x - 0.1).abs() < 1e-8, "x={x}");
        }
    }

    #[test]
    fn pow_e1_zero_steps_is_e1() {
        let g = Graph::ring(5);
        let wm = local_degree_weights(&g);
        let v = wm.pow_e1(0);
        assert_eq!(v[0], 1.0);
        assert!(v[1..].iter().all(|&x| x == 0.0));
    }
}
