//! Consensus averaging: weight matrices, mixing time, schedules, engine.
pub mod engine;
pub mod mixing;
pub mod schedule;
pub mod weights;

pub use engine::{
    average_consensus, consensus_rounds, sparse_consensus_rounds,
    sparse_faulty_consensus_rounds, ConsensusOutcome,
};
pub use mixing::{mixing_time, slem};
pub use schedule::Schedule;
pub use weights::{
    local_degree_weights, max_degree_weights, sparse_active_spectral_gap,
    sparse_local_degree_weights, SparseWeights, WeightMatrix,
};
