//! # DPSA — Distributed Principal Subspace Analysis
//!
//! Reproduction of Gang, Xiang & Bajwa, *"Distributed Principal Subspace
//! Analysis for Partitioned Big Data"* (IEEE TSIPN 2021): S-DOT, SA-DOT and
//! F-DOT plus all evaluation baselines, over an in-process distributed
//! network substrate with exact P2P communication accounting and an
//! MPI-like threaded runtime for straggler studies.
//!
//! See DESIGN.md for the architecture and EXPERIMENTS.md for the
//! paper-vs-measured results.
//!
//! Every `unsafe` operation must sit inside an explicit block with a
//! `// SAFETY:` comment; `cargo run -p xtask -- lint` audits this
//! (ROADMAP "Static invariants") and inventories all sites.
#![deny(unsafe_op_in_unsafe_fn)]
#![warn(unused_unsafe)]
pub mod consensus;
pub mod fault;
pub mod graph;
pub mod linalg;
pub mod network;
pub mod util;
pub mod data;
pub mod algorithms;
pub mod metrics;
pub mod runtime;
pub mod experiments;
pub mod config;
