//! B-DOT — Block-wise Distributed Orthogonal iTeration.
//!
//! **Extension implementing the paper's stated future work** (Section VI):
//! *"Randomly block-wise partitioned data, i.e., data partitioned by both
//! samples and features, can be a possible way to handle big data that is
//! massive in both dimension and size."*
//!
//! Setup: a `R × C` grid of nodes; node (i, j) holds the block
//! `X_{ij} ∈ R^{d_i × n_j}` (feature slice i of sample batch j). The OI
//! update `V = M Q = Σ_j X_{·j} X_{·j}ᵀ Q` factors into the two consensus
//! patterns the paper develops:
//!
//! 1. **column phase** (F-DOT-style, within each sample batch j): nodes of
//!    column j hold feature slices of `X_{·j}`, so
//!    `u_j = X_{·j}ᵀ Q = Σ_i X_{ij}ᵀ Q_i` — a consensus **sum over the
//!    column group** with n_j×r messages;
//! 2. **row phase** (S-DOT-style, within each feature slice i):
//!    `V_i = Σ_j X_{ij} u_j` — each node computes its local product, then a
//!    consensus **sum over the row group** with d_i×r messages;
//! 3. orthonormalization of the feature-stacked V via the distributed QR
//!    (push-sum Gram over the whole grid + local Cholesky), as in F-DOT.
//!
//! Each phase's consensus runs on the subgraph induced on the group.
//! Group topologies are configurable through [`BdotConfig`] — complete
//! (the natural rack/row fabric), ring, star, path, 2-D grid, or
//! Erdős–Rényi ([`GroupTopo`]); the whole-grid QR network can be the
//! literal `R × C` mesh. Groups are built on their **exact** member
//! counts: a 1-node group (R=1 or C=1 grids) has no edges and sends no
//! messages, so `total_messages` and the trace's `p2p_avg` count exactly
//! `rounds × Σ_i deg(i)` real messages — directly comparable with the
//! F-DOT / S-DOT columns of Tables I–V (the seed padded degenerate groups
//! to 2 nodes with phantom members whose traffic inflated both counters).
//! With `R = 1` B-DOT degenerates to (a consensus-flavored) F-DOT; with
//! `C = 1` each column phase is local and it behaves like a
//! feature-sharded S-DOT.

use crate::graph::GroupTopo;
use crate::linalg::chol::{cholesky_into, solve_r_right_into};
use crate::linalg::Mat;
use crate::metrics::subspace::subspace_error;
use crate::metrics::trace::{IterRecord, RunTrace};
use crate::network::sim::SyncNetwork;
use crate::util::rng::Rng;

/// A block-partitioned PSA instance on an `R × C` node grid.
#[derive(Clone, Debug)]
pub struct BlockSetting {
    /// `blocks[i][j] = X_{ij} ∈ R^{d_i × n_j}`.
    pub blocks: Vec<Vec<Mat>>,
    /// Feature offsets (length R+1).
    pub row_offsets: Vec<usize>,
    /// Top-r eigenspace of `M = X Xᵀ` (evaluation only).
    pub truth: Mat,
    /// Common init (d × r); row group i uses its slice.
    pub q_init: Mat,
    pub r: usize,
}

impl BlockSetting {
    /// Partition a full data matrix into an `rows × cols` block grid.
    pub fn new(x: &Mat, rows: usize, cols: usize, r: usize, rng: &mut Rng) -> BlockSetting {
        let feature_parts = crate::data::partition::partition_features(x, rows);
        let mut blocks = Vec::with_capacity(rows);
        let mut row_offsets = vec![0usize];
        for fp in &feature_parts {
            blocks.push(crate::data::partition::partition_samples(fp, cols));
            row_offsets.push(row_offsets.last().unwrap() + fp.rows);
        }
        let cov = crate::linalg::CovOp::Samples { x: x.clone(), scale: 1.0 };
        let truth =
            crate::data::synthetic::empirical_truth(std::slice::from_ref(&cov), r, 600);
        let q_init = Mat::random_orthonormal(x.rows, r, rng);
        BlockSetting { blocks, row_offsets, truth, q_init, r }
    }

    pub fn grid(&self) -> (usize, usize) {
        (self.blocks.len(), self.blocks[0].len())
    }

    pub fn d(&self) -> usize {
        *self.row_offsets.last().unwrap()
    }

    /// Row-group slice of a stacked `d × r` matrix.
    pub fn row_slice(&self, m: &Mat, i: usize) -> Mat {
        m.rows_range(self.row_offsets[i], self.row_offsets[i + 1])
    }
}

#[derive(Clone, Copy, Debug)]
pub struct BdotConfig {
    /// Consensus rounds for the column (F-DOT-style) phase.
    pub t_col: usize,
    /// Consensus rounds for the row (S-DOT-style) phase.
    pub t_row: usize,
    /// Push-sum rounds for the distributed QR.
    pub t_ps: usize,
    pub t_o: usize,
    pub record_every: usize,
    /// Topology of each column-group network (size R).
    pub col_topo: GroupTopo,
    /// Topology of each row-group network (size C).
    pub row_topo: GroupTopo,
    /// Topology of the whole-grid network behind the distributed QR
    /// ([`GroupTopo::Grid`] means the literal `R × C` mesh).
    pub grid_topo: GroupTopo,
    /// Seed for randomized group topologies (Erdős–Rényi sampling).
    pub topo_seed: u64,
}

impl BdotConfig {
    pub fn new(t_o: usize) -> BdotConfig {
        BdotConfig {
            t_col: 30,
            t_row: 30,
            t_ps: 40,
            t_o,
            record_every: 1,
            col_topo: GroupTopo::Complete,
            row_topo: GroupTopo::Complete,
            grid_topo: GroupTopo::Complete,
            topo_seed: 0xb_d07,
        }
    }

    /// Use `topo` for all three group networks. Slow-mixing families
    /// (ring/path on larger grids) may need more `t_ps` rounds for the
    /// push-sum QR to keep its accuracy — set it explicitly.
    pub fn with_topology(mut self, topo: GroupTopo) -> BdotConfig {
        self.col_topo = topo;
        self.row_topo = topo;
        self.grid_topo = topo;
        self
    }
}

/// Result of a B-DOT run: per-row-group Q blocks (consistent across the
/// row's nodes) and the trace.
pub struct BdotRun {
    pub q_rows: Vec<Mat>,
    pub trace: RunTrace,
    /// Total messages sent across all grid nodes (algorithm traffic on
    /// real group members only — no phantom nodes exist to pad it).
    pub total_messages: u64,
}

/// Run B-DOT. Row / column / grid group networks are built from
/// [`BdotConfig`]'s topology specs on their exact member counts; all
/// messages are counted by the same P2P machinery as Algorithms 1–2.
pub fn run_bdot(setting: &BlockSetting, cfg: &BdotConfig) -> BdotRun {
    let (rows, cols) = setting.grid();
    let r = setting.r;
    // One network per column group (size rows) for phase 1,
    // one per row group (size cols) for phase 2,
    // one over all nodes for the distributed QR.
    let col_graph = cfg.col_topo.build(rows, cfg.topo_seed);
    let row_graph = cfg.row_topo.build(cols, cfg.topo_seed ^ 1);
    let grid_graph = cfg.grid_topo.build_rect(rows, cols, cfg.topo_seed ^ 2);
    let mut col_nets: Vec<SyncNetwork> =
        (0..cols).map(|_| SyncNetwork::new(col_graph.clone())).collect();
    let mut row_nets: Vec<SyncNetwork> =
        (0..rows).map(|_| SyncNetwork::new(row_graph.clone())).collect();
    let mut grid_net = SyncNetwork::new(grid_graph);

    // Per (row, col) copy of the row's Q block — nodes in the same row
    // keep nominally identical copies (they are exchanged in phase 2).
    let mut q: Vec<Vec<Mat>> = (0..rows)
        .map(|i| (0..cols).map(|_| setting.row_slice(&setting.q_init, i)).collect())
        .collect();

    let mut trace = RunTrace::new("B-DOT");
    let mut total = 0usize;
    // Metric-side orthonormalization of the stacked estimate: `--qr`
    // kernel, snapshotted once per run.
    let qr_policy = crate::linalg::qr::default_qr_policy();

    // Persistent workspace, shaped once and reused every outer iteration.
    let mut u: Vec<Vec<Mat>> = (0..cols)
        .map(|j| {
            let n_j = setting.blocks[0][j].cols;
            (0..rows).map(|_| Mat::zeros(n_j, r)).collect()
        })
        .collect();
    let mut v: Vec<Vec<Mat>> = (0..rows)
        .map(|i| {
            let d_i = setting.blocks[i][0].rows;
            (0..cols).map(|_| Mat::zeros(d_i, r)).collect()
        })
        .collect();
    let mut grams: Vec<Mat> = (0..rows * cols).map(|_| Mat::zeros(r, r)).collect();
    let mut gram_tmp = Mat::zeros(r, r);
    let mut kbuf = Mat::zeros(r, r);
    let mut chol_buf = Mat::zeros(r, r);
    let mut qi_buf = Mat::zeros(0, 0);

    for t in 1..=cfg.t_o {
        // ---- phase 1 (column groups): u_j = Σ_i X_ijᵀ Q_i  (n_j × r) ----
        for j in 0..cols {
            for (i, slot) in u[j].iter_mut().enumerate() {
                setting.blocks[i][j].t_matmul_into(&q[i][j], slot);
            }
            col_nets[j].consensus_sum(&mut u[j], cfg.t_col);
        }
        total += cfg.t_col;

        // ---- phase 2 (row groups): V_i = Σ_j X_ij u_j  (d_i × r) --------
        for i in 0..rows {
            for (j, slot) in v[i].iter_mut().enumerate() {
                setting.blocks[i][j].matmul_into(&u[j][i], slot);
            }
            row_nets[i].consensus_sum(&mut v[i], cfg.t_row);
        }
        total += cfg.t_row;

        // ---- phase 3: distributed QR over the grid ----------------------
        // Each grid node (i, j) holds V_i (agreed within the row); the Gram
        // K = Σ_i V_iᵀ V_i is push-summed over the whole grid with each
        // row's contribution split across its C nodes.
        for i in 0..rows {
            v[i][0].t_matmul_into(&v[i][0], &mut gram_tmp);
            gram_tmp.scale_inplace(1.0 / cols as f64);
            for j in 0..cols {
                grams[i * cols + j].copy_from(&gram_tmp);
            }
        }
        grid_net.ratio_consensus_sum(&mut grams, cfg.t_ps);
        total += cfg.t_ps;
        for i in 0..rows {
            kbuf.copy_from(&grams[i * cols]);
            for a in 0..r {
                for b in (a + 1)..r {
                    let m = 0.5 * (kbuf.get(a, b) + kbuf.get(b, a));
                    kbuf.set(a, b, m);
                    kbuf.set(b, a, m);
                }
            }
            if cholesky_into(&kbuf, &mut chol_buf) {
                solve_r_right_into(&v[i][0], &chol_buf, &mut qi_buf);
            } else {
                qi_buf.copy_from(&v[i][0]);
                qi_buf.scale_inplace(1.0 / v[i][0].fro_norm().max(1e-300));
            }
            for j in 0..cols {
                q[i][j].copy_from(&qi_buf);
            }
        }

        if t % cfg.record_every == 0 || t == cfg.t_o {
            let blocks: Vec<&Mat> = (0..rows).map(|i| &q[i][0]).collect();
            let stacked = Mat::vstack(&blocks);
            let qhat = crate::linalg::qr::orthonormalize_policy(&stacked, qr_policy);
            let msgs: u64 = col_nets.iter().map(|n| n.counters.total()).sum::<u64>()
                + row_nets.iter().map(|n| n.counters.total()).sum::<u64>()
                + grid_net.counters.total();
            trace.push(IterRecord {
                outer: t,
                total_iters: total,
                error: subspace_error(&setting.truth, &qhat),
                p2p_avg: msgs as f64 / (rows * cols) as f64,
            });
        }
    }

    let q_rows: Vec<Mat> = (0..rows).map(|i| q[i][0].clone()).collect();
    let total_messages = col_nets.iter().map(|n| n.counters.total()).sum::<u64>()
        + row_nets.iter().map(|n| n.counters.total()).sum::<u64>()
        + grid_net.counters.total();
    BdotRun { q_rows, trace, total_messages }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::spectrum::Spectrum;
    use crate::data::synthetic::SyntheticDataset;

    fn setting(seed: u64, d: usize, n: usize, r: usize, rows: usize, cols: usize) -> BlockSetting {
        let mut rng = Rng::new(seed);
        let spec = Spectrum::with_gap(d, r, 0.5);
        let ds = SyntheticDataset::full(&spec, n, 1, &mut rng);
        BlockSetting::new(&ds.parts[0], rows, cols, r, &mut rng)
    }

    #[test]
    fn bdot_converges_2x2() {
        let s = setting(1, 12, 400, 3, 2, 2);
        let run = run_bdot(&s, &BdotConfig::new(60));
        assert!(run.trace.final_error() < 1e-8, "err={}", run.trace.final_error());
        assert!(run.total_messages > 0);
    }

    #[test]
    fn bdot_converges_3x4_grid() {
        let s = setting(2, 12, 360, 3, 3, 4);
        let run = run_bdot(&s, &BdotConfig::new(60));
        assert!(run.trace.final_error() < 1e-7, "err={}", run.trace.final_error());
    }

    #[test]
    fn bdot_row_blocks_stack_orthonormal() {
        let s = setting(3, 10, 300, 2, 2, 3);
        let run = run_bdot(&s, &BdotConfig::new(50));
        let refs: Vec<&Mat> = run.q_rows.iter().collect();
        let stacked = Mat::vstack(&refs);
        let gram = stacked.t_matmul(&stacked);
        assert!(gram.dist_fro(&Mat::eye(2)) < 1e-5, "{}", gram.dist_fro(&Mat::eye(2)));
    }

    #[test]
    fn bdot_single_row_matches_fdot_accuracy() {
        // R=1 degenerate: feature dimension is whole at each node; B-DOT
        // should converge like F-DOT on the same data. Column groups have
        // one member each — no messages, no phantom padding.
        let s = setting(4, 10, 400, 3, 1, 4);
        let run = run_bdot(&s, &BdotConfig::new(60));
        assert!(run.trace.final_error() < 1e-8, "err={}", run.trace.final_error());
    }

    #[test]
    fn bdot_single_col_matches_sdot_accuracy() {
        let s = setting(5, 10, 400, 3, 4, 1);
        let run = run_bdot(&s, &BdotConfig::new(60));
        assert!(run.trace.final_error() < 1e-8, "err={}", run.trace.final_error());
    }

    #[test]
    fn bdot_error_decreases_monotonically_at_scale() {
        let s = setting(6, 16, 480, 4, 2, 2);
        let run = run_bdot(&s, &BdotConfig::new(40));
        let first = run.trace.records.first().unwrap().error;
        let last = run.trace.final_error();
        assert!(last < 1e-4 * first, "first={first} last={last}");
    }

    #[test]
    fn bdot_counters_exact_rounds_times_degree() {
        // `total_messages` must equal rounds × Σ_i deg(i) over the real
        // group graphs — zero phantom-node traffic, including on the R=1
        // and C=1 grids that the paper compares against F-DOT / S-DOT.
        for &(rows, cols) in &[(1usize, 4usize), (4, 1), (2, 3)] {
            for topo in [GroupTopo::Complete, GroupTopo::Ring, GroupTopo::Star] {
                let s = setting(8, 12, 360, 3, rows, cols);
                let mut cfg = BdotConfig::new(4).with_topology(topo);
                cfg.record_every = 4;
                let run = run_bdot(&s, &cfg);
                let col_g = topo.build(rows, cfg.topo_seed);
                let row_g = topo.build(cols, cfg.topo_seed ^ 1);
                let grid_g = topo.build_rect(rows, cols, cfg.topo_seed ^ 2);
                let per_outer = cols * cfg.t_col * 2 * col_g.edge_count()
                    + rows * cfg.t_row * 2 * row_g.edge_count()
                    + cfg.t_ps * 2 * grid_g.edge_count();
                assert_eq!(
                    run.total_messages,
                    (cfg.t_o * per_outer) as u64,
                    "rows={rows} cols={cols} topo={topo:?}"
                );
            }
        }
    }

    #[test]
    fn bdot_converges_on_ring_groups() {
        let s = setting(9, 12, 360, 3, 3, 4);
        let mut cfg = BdotConfig::new(60).with_topology(GroupTopo::Ring);
        cfg.t_ps = 160; // ring(12) grid net mixes slowly (λ₂ ≈ 0.91)
        let run = run_bdot(&s, &cfg);
        assert!(run.trace.final_error() < 1e-5, "err={}", run.trace.final_error());
    }

    #[test]
    fn bdot_converges_on_grid_groups() {
        let s = setting(10, 12, 480, 3, 2, 4);
        let mut cfg = BdotConfig::new(60).with_topology(GroupTopo::Grid);
        cfg.t_ps = 160; // 2×4 mesh push-sum floor well below the target
        let run = run_bdot(&s, &cfg);
        assert!(run.trace.final_error() < 1e-6, "err={}", run.trace.final_error());
    }

    #[test]
    fn bdot_star_groups_converge() {
        // Hub-mediated mixing is slow (λ₂ = 8/9 on the 9-node star grid
        // net), so the QR push-sum needs more rounds than complete groups
        // — but the same grid then converges to the same subspace.
        let s = setting(11, 12, 360, 3, 3, 3);
        let mut cfg = BdotConfig::new(30).with_topology(GroupTopo::Star);
        cfg.t_col = 60;
        cfg.t_row = 60;
        cfg.t_ps = 160;
        let run = run_bdot(&s, &cfg);
        assert!(run.trace.final_error() < 1e-5, "err={}", run.trace.final_error());
    }
}
