//! F-DOT (Algorithm 2) — distributed orthogonal iteration for
//! **feature-wise** partitioned data, plus the distributed QR it relies on.
//!
//! Node i holds `X_i ∈ R^{d_i×n}` (a slice of every sample's features) and
//! estimates the matching rows `Q_{f,i} ∈ R^{d_i×r}` of the global
//! eigenbasis. One outer iteration (eq. 4):
//!
//! 1. `Z_i = X_iᵀ Q_{f,i}` (n×r), consensus-averaged over the network and
//!    rescaled to estimate `S = Σ_j X_jᵀ Q_{f,j}`;
//! 2. `V_i = X_i S_i` (d_i×r);
//! 3. distributed QR [12]: push-sum the Gram `K = Σ_i V_iᵀ V_i` (r×r
//!    messages), Cholesky `K = RᵀR` locally, `Q_{f,i} = V_i R⁻¹` —
//!    orthonormalizing the *stacked* `V` without collating it anywhere.

use crate::data::partition::feature_offsets;
use crate::linalg::chol::{cholesky_into, solve_r_right_into};
use crate::linalg::{CovOp, Mat};
use crate::metrics::subspace::subspace_error;
use crate::metrics::trace::{IterRecord, RunTrace};
use crate::network::sim::SyncNetwork;
use crate::runtime::pool::DisjointSlice;
use crate::util::rng::Rng;

/// A feature-wise distributed PSA instance.
#[derive(Clone, Debug)]
pub struct FeatureSetting {
    /// Per-node feature blocks `X_i ∈ R^{d_i×n}`.
    pub parts: Vec<Mat>,
    /// Row offsets of each block in the stacked `X`.
    pub offsets: Vec<usize>,
    /// Top-r eigenspace of `M = X Xᵀ` (ground truth for the error metric).
    pub truth: Mat,
    /// Common initialization `Q_init ∈ R^{d×r}` (nodes take their slices).
    pub q_init: Mat,
    pub r: usize,
}

impl FeatureSetting {
    pub fn new(parts: Vec<Mat>, r: usize, rng: &mut Rng) -> FeatureSetting {
        let d: usize = parts.iter().map(|p| p.rows).sum();
        let n = parts[0].cols;
        let offsets = {
            // Not necessarily balanced; build from actual part sizes.
            let mut offs = vec![0usize];
            for p in &parts {
                assert_eq!(p.cols, n, "all nodes must hold all samples");
                offs.push(offs.last().unwrap() + p.rows);
            }
            offs
        };
        // Ground truth from the stacked data (evaluation only).
        let refs: Vec<&Mat> = parts.iter().collect();
        let x = Mat::vstack(&refs);
        let cov = CovOp::Samples { x, scale: 1.0 };
        let truth = crate::data::synthetic::empirical_truth(std::slice::from_ref(&cov), r, 600);
        let q_init = Mat::random_orthonormal(d, r, rng);
        FeatureSetting { parts, offsets, truth, q_init, r }
    }

    pub fn d(&self) -> usize {
        *self.offsets.last().unwrap()
    }

    pub fn n_nodes(&self) -> usize {
        self.parts.len()
    }

    /// Node i's slice of a stacked `d×r` matrix.
    pub fn slice(&self, m: &Mat, i: usize) -> Mat {
        m.rows_range(self.offsets[i], self.offsets[i + 1])
    }
}

/// Sanity helper for `feature_offsets` consistency with balanced splits.
pub fn balanced_offsets(d: usize, nodes: usize) -> Vec<usize> {
    feature_offsets(d, nodes)
}

#[derive(Clone, Copy, Debug)]
pub struct FdotConfig {
    /// Consensus rounds for the n×r averaging (step 9).
    pub t_c: usize,
    /// Push-sum rounds for the distributed QR (step 12).
    pub t_ps: usize,
    pub t_o: usize,
    pub record_every: usize,
}

impl FdotConfig {
    pub fn new(t_o: usize) -> FdotConfig {
        FdotConfig { t_c: 50, t_ps: 50, t_o, record_every: 1 }
    }
}

/// Distributed QR of the implicitly stacked `V = [V_1; …; V_N]`:
/// push-sum the r×r Gram, factor locally, solve. Returns per-node Q blocks.
/// Convenience wrapper over [`distributed_qr_into`].
pub fn distributed_qr(
    net: &mut SyncNetwork,
    v: &[Mat],
    t_ps: usize,
) -> Vec<Mat> {
    let n = v.len();
    let mut grams = vec![Mat::zeros(0, 0); n];
    let mut chol = vec![Mat::zeros(0, 0); n];
    let mut q = vec![Mat::zeros(0, 0); n];
    distributed_qr_into(net, v, t_ps, &mut grams, &mut chol, &mut q);
    q
}

/// Allocation-free distributed QR into caller-provided per-node buffers
/// (`grams`, `chol`, `q_out` are reshaped in place). Per-node Gram,
/// Cholesky and triangular solve fan out across the network's node pool.
pub fn distributed_qr_into(
    net: &mut SyncNetwork,
    v: &[Mat],
    t_ps: usize,
    grams: &mut Vec<Mat>,
    chol: &mut [Mat],
    q_out: &mut [Mat],
) {
    let n = v.len();
    assert_eq!(grams.len(), n);
    assert_eq!(chol.len(), n);
    assert_eq!(q_out.len(), n);
    // Local Grams `V_iᵀ V_i`, node-parallel.
    {
        let gs = DisjointSlice::new(grams.as_mut_slice());
        net.pool().run_chunks(n, &|lo, hi| {
            for i in lo..hi {
                // SAFETY: index i belongs to exactly one chunk.
                v[i].t_matmul_into(&v[i], unsafe { gs.get_mut(i) });
            }
        });
    }
    net.ratio_consensus_sum(grams, t_ps);
    // Symmetrize (consensus noise), factor and solve, node-parallel.
    {
        let gs = DisjointSlice::new(grams.as_mut_slice());
        let cs = DisjointSlice::new(chol);
        let qs = DisjointSlice::new(q_out);
        net.pool().run_chunks(n, &|lo, hi| {
            for i in lo..hi {
                // SAFETY: index i belongs to exactly one chunk.
                let (ks, ci, qi) = unsafe { (gs.get_mut(i), cs.get_mut(i), qs.get_mut(i)) };
                for a in 0..ks.rows {
                    for b in (a + 1)..ks.cols {
                        let m = 0.5 * (ks.get(a, b) + ks.get(b, a));
                        ks.set(a, b, m);
                        ks.set(b, a, m);
                    }
                }
                if cholesky_into(ks, ci) {
                    solve_r_right_into(&v[i], ci, qi);
                } else {
                    // Numerically indefinite Gram (very inexact consensus):
                    // fall back to scaling by the Frobenius norm to stay
                    // finite.
                    qi.copy_from(&v[i]);
                    qi.scale_inplace(1.0 / v[i].fro_norm().max(1e-300));
                }
            }
        });
    }
}

/// Run Algorithm 2.
///
/// All per-iteration buffers (`Z_i`, `V_i`, Grams, Cholesky factors) are
/// allocated once before the loop and reused, so steady-state outer
/// iterations are allocation-free; per-node products fan out across the
/// network's node pool with bitwise-deterministic results.
pub fn run_fdot(
    net: &mut SyncNetwork,
    setting: &FeatureSetting,
    cfg: &FdotConfig,
) -> (Vec<Mat>, RunTrace) {
    let n = net.n();
    assert_eq!(setting.n_nodes(), n);
    let mut q: Vec<Mat> = (0..n).map(|i| setting.slice(&setting.q_init, i)).collect();
    let mut trace = RunTrace::new("F-DOT");
    let mut total = 0usize;
    // Persistent workspace (shaped on first use, reused thereafter).
    let mut z = vec![Mat::zeros(0, 0); n];
    let mut v = vec![Mat::zeros(0, 0); n];
    let mut grams = vec![Mat::zeros(0, 0); n];
    let mut chol = vec![Mat::zeros(0, 0); n];
    // Metric-side orthonormalization of the stacked estimate: `--qr`
    // kernel, snapshotted once per run.
    let qr_policy = crate::linalg::qr::default_qr_policy();

    for t in 1..=cfg.t_o {
        // Step 5: Z_i = X_iᵀ Q_i  (n×r), node-parallel.
        {
            let zs = DisjointSlice::new(z.as_mut_slice());
            let parts = &setting.parts;
            let qref = &q;
            net.pool().run_chunks(n, &|lo, hi| {
                for i in lo..hi {
                    // SAFETY: index i belongs to exactly one chunk.
                    parts[i].t_matmul_into(&qref[i], unsafe { zs.get_mut(i) });
                }
            });
        }
        // Steps 6–11: consensus to the sum Σ_j X_jᵀ Q_j.
        net.consensus_sum(&mut z, cfg.t_c);
        total += cfg.t_c;
        // Step 11: V_i = X_i Ẑ_i, node-parallel.
        {
            let vs = DisjointSlice::new(v.as_mut_slice());
            let parts = &setting.parts;
            let zref = &z;
            net.pool().run_chunks(n, &|lo, hi| {
                for i in lo..hi {
                    // SAFETY: index i belongs to exactly one chunk.
                    parts[i].matmul_into(&zref[i], unsafe { vs.get_mut(i) });
                }
            });
        }
        // Step 12: distributed QR.
        distributed_qr_into(net, &v, cfg.t_ps, &mut grams, &mut chol, &mut q);
        total += cfg.t_ps;

        if t % cfg.record_every == 0 || t == cfg.t_o {
            let refs: Vec<&Mat> = q.iter().collect();
            let stacked = Mat::vstack(&refs);
            // Orthonormality is only approximate under inexact consensus;
            // orthonormalize the stacked copy for a fair angle metric.
            let qhat = crate::linalg::qr::orthonormalize_policy(&stacked, qr_policy);
            trace.push(IterRecord {
                outer: t,
                total_iters: total,
                error: subspace_error(&setting.truth, &qhat),
                p2p_avg: net.counters.avg(),
            });
        }
    }
    (q, trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::partition_features;
    use crate::data::spectrum::Spectrum;
    use crate::data::synthetic::SyntheticDataset;
    use crate::graph::Graph;

    fn feature_setting(seed: u64, d: usize, r: usize, nodes: usize) -> (FeatureSetting, Rng) {
        let mut rng = Rng::new(seed);
        let spec = Spectrum::with_gap(d, r, 0.5);
        let ds = SyntheticDataset::full(&spec, 500, 1, &mut rng);
        let parts = partition_features(&ds.parts[0], nodes);
        let s = FeatureSetting::new(parts, r, &mut rng);
        (s, rng)
    }

    #[test]
    fn fdot_converges() {
        let (s, mut rng) = feature_setting(1, 10, 3, 10);
        let g = Graph::erdos_renyi(10, 0.5, &mut rng);
        let mut net = SyncNetwork::new(g);
        let (_, trace) = run_fdot(&mut net, &s, &FdotConfig::new(60));
        assert!(trace.final_error() < 1e-8, "err={}", trace.final_error());
    }

    #[test]
    fn fdot_blocks_stack_to_orthonormal() {
        let (s, mut rng) = feature_setting(2, 12, 3, 4);
        let g = Graph::complete(4);
        let _ = &mut rng;
        let mut net = SyncNetwork::new(g);
        let (q, _) = run_fdot(&mut net, &s, &FdotConfig::new(40));
        let refs: Vec<&Mat> = q.iter().collect();
        let stacked = Mat::vstack(&refs);
        let gram = stacked.t_matmul(&stacked);
        assert!(gram.dist_fro(&Mat::eye(3)) < 1e-4, "{}", gram.dist_fro(&Mat::eye(3)));
    }

    #[test]
    fn distributed_qr_matches_centralized() {
        let mut rng = Rng::new(3);
        let g = Graph::complete(5);
        let mut net = SyncNetwork::new(g);
        let full = Mat::gauss(20, 4, &mut rng);
        let parts = partition_features(&full, 5);
        let q_parts = distributed_qr(&mut net, &parts, 150);
        let refs: Vec<&Mat> = q_parts.iter().collect();
        let stacked = Mat::vstack(&refs);
        let (qh, _) = crate::linalg::qr::householder_qr(&full);
        // Same column space; Cholesky-QR and Householder agree up to signs
        // fixed by positive-diagonal convention.
        assert!(subspace_error(&qh, &crate::linalg::qr::orthonormalize(&stacked)) < 1e-8);
    }

    #[test]
    fn fdot_message_sizes_tracked() {
        // Step 9 messages are n×r; step 12 messages are r×r+1.
        let (s, mut rng) = feature_setting(4, 8, 2, 4);
        let _ = &mut rng;
        let g = Graph::ring(4);
        let mut net = SyncNetwork::new(g);
        let cfg = FdotConfig { t_c: 3, t_ps: 2, t_o: 1, record_every: 1 };
        let (_, _) = run_fdot(&mut net, &s, &cfg);
        let n_samples = 500;
        let expected_payload =
            (3 * (n_samples * 2) + 2 * (2 * 2 + 1)) * 2; // rounds×elems×degree
        assert_eq!(net.counters.payload[0], expected_payload as u64);
    }

    #[test]
    fn one_feature_per_node_works() {
        // Fig. 6 setting: d = N, each node carries exactly one feature.
        let (s, mut rng) = feature_setting(5, 10, 2, 10);
        assert!(s.parts.iter().all(|p| p.rows == 1));
        let g = Graph::erdos_renyi(10, 0.5, &mut rng);
        let mut net = SyncNetwork::new(g);
        let (_, trace) = run_fdot(&mut net, &s, &FdotConfig::new(50));
        assert!(trace.final_error() < 1e-6);
    }
}
