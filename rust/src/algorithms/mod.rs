//! The paper's algorithms and all evaluation baselines.
//!
//! Sample-wise partitioned data (Section III-A):
//! * [`sdot`] — **S-DOT** (Alg. 1) and **SA-DOT** (adaptive schedule).
//! * [`oi`] — centralized orthogonal iteration and sequential power method.
//! * [`seqdistpm`] — sequential distributed power method ([13]-style).
//! * [`dsa`] — distributed Sanger's algorithm [19].
//! * [`dpgd`] — distributed projected gradient descent.
//! * [`deepca`] — DeEPCA gradient-tracking subspace iteration [27].
//!
//! Feature-wise partitioned data (Section III-B):
//! * [`fdot`] — **F-DOT** (Alg. 2) with the push-sum distributed QR.
//! * [`dpm_feature`] — sequential distributed power method (d-PM, [10]).

pub mod bdot;
pub mod common;
pub mod deepca;
pub mod dpgd;
pub mod dpm_feature;
pub mod dsa;
pub mod fdot;
pub mod oi;
pub mod sdot;
pub mod seqdistpm;

pub use common::SampleSetting;
pub use sdot::{run_sadot, run_sdot, SdotConfig, SdotRun};
