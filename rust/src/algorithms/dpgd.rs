//! DPGD — Distributed Projected Gradient Descent.
//!
//! Gradient baseline from the paper (Section V): one mixing round plus a
//! gradient-ascent step on the trace objective `f_i(Q) = Tr(QᵀM_iQ)`
//! (Nedić–Ozdaglar-style distributed (sub)gradient [35]), followed by a
//! projection onto the Stiefel manifold via QR:
//!
//! ```text
//! Q_i ← QR( Σ_j w_ij Q_j + α ∇f_i(Q_i) ),   ∇f_i(Q) = 2 M_i Q
//! ```
//!
//! With a constant step it converges to a neighborhood of the solution.

use super::common::SampleSetting;
use crate::linalg::Mat;
use crate::metrics::subspace::average_error;
use crate::metrics::trace::{IterRecord, RunTrace};
use crate::network::sim::SyncNetwork;
use crate::runtime::pool::DisjointSlice;
use crate::runtime::workspace::{node_scratch, NodeScratch};

#[derive(Clone, Copy, Debug)]
pub struct DpgdConfig {
    pub alpha: f64,
    pub iters: usize,
    pub record_every: usize,
}

impl DpgdConfig {
    pub fn new(iters: usize) -> DpgdConfig {
        DpgdConfig { alpha: 0.05, iters, record_every: 1 }
    }
}

pub fn run_dpgd(
    net: &mut SyncNetwork,
    setting: &SampleSetting,
    cfg: &DpgdConfig,
) -> (Vec<Mat>, RunTrace) {
    let n = net.n();
    let mut q: Vec<Mat> = vec![setting.q_init.clone(); n];
    let mut trace = RunTrace::new("DPGD");

    // Persistent per-node buffers (gradients + QR scratch); the Stiefel
    // projection uses the process-wide `--qr` kernel, snapshotted once.
    let mut grads = vec![Mat::zeros(0, 0); n];
    let mut scratch: Vec<NodeScratch> = node_scratch(n);
    let qr_policy = crate::linalg::qr::default_qr_policy();

    for t in 1..=cfg.iters {
        // ∇f_i(Q_i) = 2 M_i Q_i, node-parallel.
        {
            let gs = DisjointSlice::new(grads.as_mut_slice());
            let scr = DisjointSlice::new(scratch.as_mut_slice());
            let qref = &q;
            let covs = &setting.covs;
            net.pool().run_chunks(n, &|lo, hi| {
                for i in lo..hi {
                    // SAFETY: index i belongs to exactly one chunk.
                    let (g, s) = unsafe { (gs.get_mut(i), scr.get_mut(i)) };
                    covs[i].apply_into(&qref[i], g, &mut s.t0);
                    g.scale_inplace(2.0);
                }
            });
        }
        net.consensus(&mut q, 1);
        // Gradient step + Stiefel projection (QR), node-parallel.
        {
            let qs = DisjointSlice::new(q.as_mut_slice());
            let scr = DisjointSlice::new(scratch.as_mut_slice());
            let gref = &grads;
            let alpha = cfg.alpha;
            net.pool().run_chunks(n, &|lo, hi| {
                for i in lo..hi {
                    // SAFETY: index i belongs to exactly one chunk.
                    let (qi, s) = unsafe { (qs.get_mut(i), scr.get_mut(i)) };
                    qi.axpy(alpha, &gref[i]);
                    crate::linalg::qr::orthonormalize_policy_into(
                        qi, &mut s.t1, &mut s.qr, qr_policy,
                    );
                    std::mem::swap(qi, &mut s.t1);
                }
            });
        }
        if t % cfg.record_every == 0 || t == cfg.iters {
            trace.push(IterRecord {
                outer: t,
                total_iters: t,
                error: average_error(&setting.truth, &q),
                p2p_avg: net.counters.avg(),
            });
        }
    }
    (q, trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::spectrum::Spectrum;
    use crate::data::synthetic::SyntheticDataset;
    use crate::graph::Graph;
    use crate::util::rng::Rng;

    fn setting(seed: u64) -> (SampleSetting, Rng) {
        let mut rng = Rng::new(seed);
        let spec = Spectrum::with_gap(16, 3, 0.5);
        let ds = SyntheticDataset::full(&spec, 800, 6, &mut rng);
        let s = SampleSetting::from_parts(&ds.parts, 3, &mut rng);
        (s, rng)
    }

    #[test]
    fn dpgd_reduces_error() {
        let (s, mut rng) = setting(1);
        let g = Graph::erdos_renyi(6, 0.6, &mut rng);
        let mut net = SyncNetwork::new(g);
        let (_, trace) = run_dpgd(&mut net, &s, &DpgdConfig::new(800));
        let first = trace.records.first().unwrap().error;
        assert!(trace.final_error() < 0.2 * first);
    }

    #[test]
    fn dpgd_iterates_stay_orthonormal() {
        let (s, mut rng) = setting(2);
        let g = Graph::erdos_renyi(6, 0.6, &mut rng);
        let mut net = SyncNetwork::new(g);
        let (q, _) = run_dpgd(&mut net, &s, &DpgdConfig::new(50));
        for qi in &q {
            assert!(qi.t_matmul(qi).dist_fro(&Mat::eye(3)) < 1e-10);
        }
    }

    #[test]
    fn dpgd_plateaus_above_sdot() {
        use crate::algorithms::sdot::{run_sdot, SdotConfig};
        use crate::consensus::schedule::Schedule;

        let (s, mut rng) = setting(3);
        let g = Graph::erdos_renyi(6, 0.6, &mut rng);

        let mut net1 = SyncNetwork::new(g.clone());
        let (_, tr_dpgd) = run_dpgd(&mut net1, &s, &DpgdConfig::new(1500));

        let mut net2 = SyncNetwork::new(g);
        let (_, tr_sdot) = run_sdot(&mut net2, &s, &SdotConfig::new(Schedule::fixed(50), 60));

        assert!(tr_sdot.final_error() < tr_dpgd.final_error() * 1e-2);
    }
}
