//! d-PM — the sequential distributed power method for feature-wise
//! partitioned data (Scaglione et al. [10]), the baseline F-DOT improves on.
//!
//! Eigenvectors are estimated **one at a time**. For vector j, each power
//! iteration on `M = X Xᵀ` distributes as:
//!
//! 1. `u_i = X_iᵀ v_i ∈ R^n` locally; consensus-sum → `s ≈ Σ_i u_i`;
//! 2. `w_i = X_i s` (the node's feature-slice of `M v`);
//! 3. deflation against already-finished vectors and normalization, both of
//!    which need network scalars (`q_kᵀ v`, `‖w‖²`) — gathered with a
//!    second, small consensus phase whose messages are also counted.

use super::fdot::FeatureSetting;
use crate::linalg::Mat;
use crate::metrics::subspace::subspace_error;
use crate::metrics::trace::{IterRecord, RunTrace};
use crate::network::sim::SyncNetwork;

#[derive(Clone, Copy, Debug)]
pub struct DpmFeatureConfig {
    pub iters_per_vec: usize,
    pub t_c: usize,
    pub record_every: usize,
}

impl DpmFeatureConfig {
    pub fn new(iters_per_vec: usize) -> DpmFeatureConfig {
        DpmFeatureConfig { iters_per_vec, t_c: 50, record_every: 1 }
    }
}

pub fn run_dpm_feature(
    net: &mut SyncNetwork,
    setting: &FeatureSetting,
    cfg: &DpmFeatureConfig,
) -> (Vec<Mat>, RunTrace) {
    let n = net.n();
    let r = setting.r;
    let mut trace = RunTrace::new("d-PM");
    // Metric-side orthonormalization of the stacked estimate: `--qr`
    // kernel, snapshotted once per run.
    let qr_policy = crate::linalg::qr::default_qr_policy();
    // Per-node current estimate blocks (d_i × r), start from the init.
    let mut q: Vec<Mat> = (0..n).map(|i| setting.slice(&setting.q_init, i)).collect();
    let mut lambdas: Vec<f64> = Vec::new(); // agreed deflation weights
    let mut total = 0usize;
    let mut outer = 0usize;
    // Persistent workspace: working vector slices (d_i×1), phase-A sums,
    // local `M v` slices, and the scalar consensus payloads.
    let mut v: Vec<Mat> = (0..n).map(|i| Mat::zeros(setting.parts[i].rows, 1)).collect();
    let mut u: Vec<Mat> = vec![Mat::zeros(0, 0); n];
    let mut w: Vec<Mat> = vec![Mat::zeros(0, 0); n];
    let mut scal: Vec<Mat> = vec![Mat::zeros(0, 0); n];
    let mut norms: Vec<Mat> = vec![Mat::zeros(1, 1); n];

    for j in 0..r {
        // Working vector slice at each node.
        for i in 0..n {
            let di = setting.parts[i].rows;
            v[i].reshape_in_place(di, 1);
            for row in 0..di {
                v[i].data[row] = q[i].get(row, j);
            }
        }
        for _ in 0..cfg.iters_per_vec {
            // Phase A: consensus on u = Σ X_iᵀ v_i (n×1 messages).
            for i in 0..n {
                setting.parts[i].t_matmul_into(&v[i], &mut u[i]);
            }
            net.consensus_sum(&mut u, cfg.t_c);
            total += cfg.t_c;

            // Local slice of M v.
            for i in 0..n {
                setting.parts[i].matmul_into(&u[i], &mut w[i]);
            }

            // Phase B: network scalars — deflation dots q_kᵀ v (k<j) and the
            // squared norms of (deflated) w. Packed into one (j+1)×1 message.
            for i in 0..n {
                scal[i].reshape_in_place(j + 1, 1);
                for k in 0..j {
                    scal[i].data[k] = q[i].col_dot(k, &v[i].data);
                }
                scal[i].data[j] = 0.0; // placeholder for ‖w‖² after deflation
            }
            // First consensus to agree on the deflation dots.
            net.consensus_sum(&mut scal, cfg.t_c);
            total += cfg.t_c;
            for i in 0..n {
                for k in 0..j {
                    let dot = scal[i].get(k, 0);
                    for (row, wi) in w[i].data.iter_mut().enumerate() {
                        *wi -= lambdas[k] * dot * q[i].get(row, k);
                    }
                }
            }
            // Agree on the global norm of the deflated w.
            for i in 0..n {
                norms[i].reshape_in_place(1, 1);
                norms[i].data[0] = w[i].data.iter().map(|x| x * x).sum();
            }
            net.consensus_sum(&mut norms, cfg.t_c);
            total += cfg.t_c;
            for i in 0..n {
                let nn = norms[i].get(0, 0).max(1e-300).sqrt();
                for x in w[i].data.iter_mut() {
                    *x /= nn;
                }
                q[i].set_col(j, &w[i].data);
                v[i].copy_from(&w[i]);
            }
            outer += 1;
            if outer % cfg.record_every == 0 {
                let refs: Vec<&Mat> = q.iter().collect();
                let stacked = Mat::vstack(&refs);
                let qhat = crate::linalg::qr::orthonormalize_policy(&stacked, qr_policy);
                trace.push(IterRecord {
                    outer,
                    total_iters: total,
                    error: subspace_error(&setting.truth, &qhat),
                    p2p_avg: net.counters.avg(),
                });
            }
        }
        // λ_j = ‖Xᵀ v‖² — computable from the last phase-A consensus result:
        // re-run one phase-A to get a clean estimate.
        for i in 0..n {
            setting.parts[i].t_matmul_into(&v[i], &mut u[i]);
        }
        net.consensus_sum(&mut u, cfg.t_c);
        total += cfg.t_c;
        let lam = u[0].data.iter().map(|x| x * x).sum::<f64>();
        lambdas.push(lam);
    }
    (q, trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::partition_features;
    use crate::data::spectrum::Spectrum;
    use crate::data::synthetic::SyntheticDataset;
    use crate::graph::Graph;
    use crate::util::rng::Rng;

    fn feature_setting(seed: u64, d: usize, r: usize, nodes: usize) -> (FeatureSetting, Rng) {
        let mut rng = Rng::new(seed);
        let spec = Spectrum::with_gap(d, r, 0.4);
        let ds = SyntheticDataset::full(&spec, 500, 1, &mut rng);
        let parts = partition_features(&ds.parts[0], nodes);
        let s = FeatureSetting::new(parts, r, &mut rng);
        (s, rng)
    }

    #[test]
    fn dpm_feature_converges() {
        let (s, mut rng) = feature_setting(1, 10, 2, 5);
        let g = Graph::erdos_renyi(5, 0.6, &mut rng);
        let mut net = SyncNetwork::new(g);
        let cfg = DpmFeatureConfig { iters_per_vec: 100, t_c: 50, record_every: 10 };
        let (_, trace) = run_dpm_feature(&mut net, &s, &cfg);
        assert!(trace.final_error() < 1e-4, "err={}", trace.final_error());
    }

    #[test]
    fn fdot_beats_dpm_in_total_iterations() {
        // Fig. 6: simultaneous (F-DOT) beats sequential (d-PM).
        use crate::algorithms::fdot::{run_fdot, FdotConfig};

        let (s, mut rng) = feature_setting(2, 10, 3, 5);
        let g = Graph::erdos_renyi(5, 0.6, &mut rng);

        let mut net1 = SyncNetwork::new(g.clone());
        let (_, tr_fdot) = run_fdot(&mut net1, &s, &FdotConfig::new(80));

        let mut net2 = SyncNetwork::new(g);
        let cfg = DpmFeatureConfig { iters_per_vec: 80, t_c: 50, record_every: 5 };
        let (_, tr_dpm) = run_dpm_feature(&mut net2, &s, &cfg);

        let tol = 1e-4;
        let a = tr_fdot.iters_to_error(tol).expect("F-DOT reaches tol");
        match tr_dpm.iters_to_error(tol) {
            Some(b) => assert!(a < b, "fdot={a} dpm={b}"),
            None => {}
        }
    }
}
