//! Shared problem setting for sample-wise partitioned algorithms.

use crate::data::synthetic::empirical_truth;
use crate::linalg::{CovOp, Mat};
use crate::util::rng::Rng;

/// A sample-wise distributed PSA instance: per-node covariances, the
/// empirical ground truth (top-r eigenspace of `Σ_i M_i`, which is what
/// every algorithm converges to), and a common initialization — the paper
/// initializes OI and all distributed variants at the same `Q_init`.
#[derive(Clone, Debug)]
pub struct SampleSetting {
    pub covs: Vec<CovOp>,
    pub truth: Mat,
    pub q_init: Mat,
    pub r: usize,
}

impl SampleSetting {
    /// Build from per-node covariance operators.
    pub fn new(covs: Vec<CovOp>, r: usize, rng: &mut Rng) -> SampleSetting {
        let d = covs[0].dim();
        let truth = empirical_truth(&covs, r, 600);
        let q_init = Mat::random_orthonormal(d, r, rng);
        SampleSetting { covs, truth, q_init, r }
    }

    /// Build from per-node sample blocks.
    pub fn from_parts(parts: &[Mat], r: usize, rng: &mut Rng) -> SampleSetting {
        let covs: Vec<CovOp> = parts.iter().map(|p| CovOp::from_samples(p.clone())).collect();
        Self::new(covs, r, rng)
    }

    pub fn n_nodes(&self) -> usize {
        self.covs.len()
    }

    pub fn d(&self) -> usize {
        self.covs[0].dim()
    }

    /// `Σ_i M_i Q` — one centralized OI update direction.
    pub fn global_apply(&self, q: &Mat) -> Mat {
        let mut v = Mat::zeros(self.d(), q.cols);
        let mut tmp = Mat::zeros(0, 0);
        let mut tmp2 = Mat::zeros(0, 0);
        self.global_apply_into(q, &mut v, &mut tmp, &mut tmp2);
        v
    }

    /// Allocation-free `out = Σ_i M_i Q` into caller-provided buffers
    /// (`tmp`/`tmp2` are per-term scratch). Arithmetic identical to
    /// [`SampleSetting::global_apply`], which delegates here.
    pub fn global_apply_into(&self, q: &Mat, out: &mut Mat, tmp: &mut Mat, tmp2: &mut Mat) {
        out.reshape_in_place(self.d(), q.cols);
        out.fill(0.0);
        for c in &self.covs {
            c.apply_into(q, tmp, tmp2);
            out.axpy(1.0, tmp);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::spectrum::Spectrum;
    use crate::data::synthetic::SyntheticDataset;
    use crate::metrics::subspace::subspace_error;

    #[test]
    fn setting_truth_is_invariant_subspace() {
        let mut rng = Rng::new(1);
        let spec = Spectrum::with_gap(12, 3, 0.5);
        let ds = SyntheticDataset::full(&spec, 300, 4, &mut rng);
        let s = SampleSetting::from_parts(&ds.parts, 3, &mut rng);
        // M * truth spans truth (invariant subspace): error of the
        // orthonormalized image vs truth is ~0.
        let img = crate::linalg::qr::orthonormalize(&s.global_apply(&s.truth));
        assert!(subspace_error(&s.truth, &img) < 1e-10);
    }

    #[test]
    fn init_is_orthonormal_and_not_truth() {
        let mut rng = Rng::new(2);
        let spec = Spectrum::with_gap(10, 3, 0.5);
        let ds = SyntheticDataset::full(&spec, 200, 3, &mut rng);
        let s = SampleSetting::from_parts(&ds.parts, 3, &mut rng);
        let g = s.q_init.t_matmul(&s.q_init);
        assert!(g.dist_fro(&Mat::eye(3)) < 1e-10);
        assert!(subspace_error(&s.truth, &s.q_init) > 1e-3);
    }

    #[test]
    fn global_apply_matches_dense_sum() {
        let mut rng = Rng::new(3);
        let spec = Spectrum::with_gap(8, 2, 0.6);
        let ds = SyntheticDataset::full(&spec, 100, 3, &mut rng);
        let s = SampleSetting::from_parts(&ds.parts, 2, &mut rng);
        let m = CovOp::sum_dense(&s.covs);
        let q = Mat::random_orthonormal(8, 2, &mut rng);
        assert!(s.global_apply(&q).dist_fro(&m.matmul(&q)) < 1e-9);
    }
}
