//! DSA — Distributed Sanger's Algorithm [19].
//!
//! A Hebbian-learning baseline: each iteration mixes neighbor estimates
//! (one consensus round) and takes a Sanger step
//!
//! ```text
//! Q_i ← Σ_j w_ij Q_j + α ( M_i Q_i − Q_i · UT(Q_iᵀ M_i Q_i) )
//! ```
//!
//! with `UT(·)` the upper-triangular (including diagonal) part. With a
//! constant step size DSA converges linearly to a **neighborhood** of the
//! true solution — visibly plateauing above S-DOT in Figs. 4/5/8/10.

use super::common::SampleSetting;
use crate::linalg::Mat;
use crate::metrics::subspace::average_error;
use crate::metrics::trace::{IterRecord, RunTrace};
use crate::network::sim::SyncNetwork;
use crate::runtime::pool::DisjointSlice;
use crate::runtime::workspace::{node_scratch, NodeScratch};

#[derive(Clone, Copy, Debug)]
pub struct DsaConfig {
    pub alpha: f64,
    pub iters: usize,
    pub record_every: usize,
}

impl DsaConfig {
    /// A reasonable default step for covariances with ‖M_i‖₂ = O(1).
    pub fn new(iters: usize) -> DsaConfig {
        DsaConfig { alpha: 0.1, iters, record_every: 1 }
    }
}

pub fn run_dsa(
    net: &mut SyncNetwork,
    setting: &SampleSetting,
    cfg: &DsaConfig,
) -> (Vec<Mat>, RunTrace) {
    let n = net.n();
    let mut q: Vec<Mat> = vec![setting.q_init.clone(); n];
    let mut trace = RunTrace::new("DSA");
    // Persistent per-node buffers: gradients + scratch (t0 = M_i Q_i,
    // t1 = Q_iᵀ M_i Q_i / its UT part, t2 = Q_i · UT(·)).
    let mut grads = vec![Mat::zeros(0, 0); n];
    let mut scratch: Vec<NodeScratch> = node_scratch(n);

    for t in 1..=cfg.iters {
        // Sanger gradient at each node (computed on the pre-mix iterate),
        // node-parallel.
        {
            let gs = DisjointSlice::new(grads.as_mut_slice());
            let scr = DisjointSlice::new(scratch.as_mut_slice());
            let qref = &q;
            let covs = &setting.covs;
            net.pool().run_chunks(n, &|lo, hi| {
                for i in lo..hi {
                    // SAFETY: index i belongs to exactly one chunk.
                    let (g, s) = unsafe { (gs.get_mut(i), scr.get_mut(i)) };
                    covs[i].apply_into(&qref[i], g, &mut s.t0); // M_i Q_i
                    qref[i].t_matmul_into(g, &mut s.t1); // Q_iᵀ M_i Q_i
                    // Keep only the upper triangle (incl. diagonal).
                    let rr = s.t1.rows;
                    for a in 1..rr {
                        for b in 0..a {
                            s.t1.set(a, b, 0.0);
                        }
                    }
                    qref[i].matmul_into(&s.t1, &mut s.t2);
                    g.axpy(-1.0, &s.t2);
                }
            });
        }
        // One consensus (mixing) round on the estimates.
        net.consensus(&mut q, 1);
        // Gradient step.
        for i in 0..n {
            q[i].axpy(cfg.alpha, &grads[i]);
        }
        if t % cfg.record_every == 0 || t == cfg.iters {
            trace.push(IterRecord {
                outer: t,
                total_iters: t,
                error: average_error(&setting.truth, &q),
                p2p_avg: net.counters.avg(),
            });
        }
    }
    (q, trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::spectrum::Spectrum;
    use crate::data::synthetic::SyntheticDataset;
    use crate::graph::Graph;
    use crate::util::rng::Rng;

    fn setting(seed: u64) -> (SampleSetting, Rng) {
        let mut rng = Rng::new(seed);
        let spec = Spectrum::with_gap(16, 3, 0.5);
        let ds = SyntheticDataset::full(&spec, 800, 6, &mut rng);
        let s = SampleSetting::from_parts(&ds.parts, 3, &mut rng);
        (s, rng)
    }

    #[test]
    fn dsa_reduces_error() {
        let (s, mut rng) = setting(1);
        let g = Graph::erdos_renyi(6, 0.6, &mut rng);
        let mut net = SyncNetwork::new(g);
        let (_, trace) = run_dsa(&mut net, &s, &DsaConfig::new(600));
        let first = trace.records.first().unwrap().error;
        let last = trace.final_error();
        assert!(last < 0.1 * first, "first={first} last={last}");
    }

    #[test]
    fn dsa_plateaus_above_sdot() {
        // DSA converges to a neighborhood; S-DOT drives error to ~0.
        use crate::algorithms::sdot::{run_sdot, SdotConfig};
        use crate::consensus::schedule::Schedule;

        let (s, mut rng) = setting(2);
        let g = Graph::erdos_renyi(6, 0.6, &mut rng);

        let mut net1 = SyncNetwork::new(g.clone());
        let (_, tr_dsa) = run_dsa(&mut net1, &s, &DsaConfig::new(1500));

        let mut net2 = SyncNetwork::new(g);
        let (_, tr_sdot) = run_sdot(&mut net2, &s, &SdotConfig::new(Schedule::fixed(50), 60));

        assert!(
            tr_sdot.final_error() < tr_dsa.final_error() * 1e-2,
            "sdot={} dsa={}",
            tr_sdot.final_error(),
            tr_dsa.final_error()
        );
    }

    /// Upper-triangular (incl. diagonal) part — reference for the
    /// in-place masking done inside the gradient kernel.
    fn upper_triangular(m: &Mat) -> Mat {
        let n = m.rows;
        let mut out = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                out.set(i, j, m.get(i, j));
            }
        }
        out
    }

    #[test]
    fn upper_triangular_extraction() {
        let m = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let ut = upper_triangular(&m);
        assert_eq!(ut, Mat::from_rows(&[&[1.0, 2.0], &[0.0, 4.0]]));
    }

    #[test]
    fn dsa_threaded_matches_serial_bitwise() {
        let (s, mut rng) = setting(4);
        let g = Graph::erdos_renyi(6, 0.6, &mut rng);
        let mut net1 = SyncNetwork::with_threads(g.clone(), 1);
        let (q1, _) = run_dsa(&mut net1, &s, &DsaConfig::new(60));
        let mut net4 = SyncNetwork::with_threads(g, 4);
        let (q4, _) = run_dsa(&mut net4, &s, &DsaConfig::new(60));
        for (a, b) in q1.iter().zip(q4.iter()) {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn one_message_per_neighbor_per_iteration() {
        let (s, mut rng) = setting(3);
        let _ = &mut rng;
        let g = Graph::ring(6);
        let mut net = SyncNetwork::new(g);
        let (_, _) = run_dsa(&mut net, &s, &DsaConfig::new(40));
        for i in 0..6 {
            assert_eq!(net.counters.sent[i], 40 * 2);
        }
    }
}
