//! DeEPCA — Decentralized Exact PCA with gradient tracking [27].
//!
//! The strongest distributed competitor in the paper's comparisons
//! (Remark 1: same algorithmic complexity as S-DOT, one log factor better
//! in communications). Each node tracks the network-average power-iteration
//! direction with a gradient-tracking recursion and runs a few **FastMix**
//! (Chebyshev-accelerated consensus) rounds per outer iteration:
//!
//! ```text
//! S_i ← FastMix( S_i + M_i Q_i^{t} − M_i Q_i^{t-1} )
//! Q_i^{t+1} = SignAdjust( QR(S_i) , Q_i^{t} )
//! ```
//!
//! The sign adjustment keeps the per-column orientation consistent across
//! iterations so the tracking differences stay meaningful.

use super::common::SampleSetting;
use crate::consensus::mixing::slem;
use crate::linalg::qr::householder_qr;
use crate::linalg::svd::sign_adjust;
use crate::linalg::Mat;
use crate::metrics::subspace::average_error;
use crate::metrics::trace::{IterRecord, RunTrace};
use crate::network::sim::SyncNetwork;

#[derive(Clone, Copy, Debug)]
pub struct DeepcaConfig {
    /// FastMix rounds per outer iteration (the paper's K; small, e.g. 3–8).
    pub mix_rounds: usize,
    pub t_o: usize,
    pub record_every: usize,
}

impl DeepcaConfig {
    pub fn new(t_o: usize) -> DeepcaConfig {
        DeepcaConfig { mix_rounds: 5, t_o, record_every: 1 }
    }
}

/// Chebyshev-accelerated consensus (FastMix). One round costs one neighbor
/// exchange, like plain consensus, but the two-term recursion contracts at
/// `(1−√(1−σ²))/(1+√(1−σ²))` per round instead of σ.
fn fastmix(net: &mut SyncNetwork, z: &mut Vec<Mat>, rounds: usize, eta: f64) {
    if rounds == 0 {
        return;
    }
    let mut prev = z.clone();
    // First round: plain mixing.
    net.consensus(z, 1);
    for _ in 1..rounds {
        // x^{k+1} = (1+η) W x^k − η x^{k-1}
        let mut wx = z.clone();
        net.consensus(&mut wx, 1);
        for i in 0..z.len() {
            let mut nxt = wx[i].scale(1.0 + eta);
            nxt.axpy(-eta, &prev[i]);
            prev[i] = z[i].clone();
            z[i] = nxt;
        }
    }
}

pub fn run_deepca(
    net: &mut SyncNetwork,
    setting: &SampleSetting,
    cfg: &DeepcaConfig,
) -> (Vec<Mat>, RunTrace) {
    let n = net.n();
    let sigma = slem(&net.weights).min(0.999_999);
    let root = (1.0 - sigma * sigma).sqrt();
    let eta = (1.0 - root) / (1.0 + root);

    let mut q: Vec<Mat> = vec![setting.q_init.clone(); n];
    let mut prev_grad: Vec<Mat> = (0..n).map(|i| setting.covs[i].apply(&q[i])).collect();
    // Tracker initialized at the local gradient, then mixed once.
    let mut s: Vec<Mat> = prev_grad.clone();
    fastmix(net, &mut s, cfg.mix_rounds, eta);

    let mut trace = RunTrace::new("DeEPCA");
    let mut total = cfg.mix_rounds;

    for t in 1..=cfg.t_o {
        // Orthonormalize the tracker with sign consistency.
        for i in 0..n {
            let (qq, _) = householder_qr(&s[i]);
            q[i] = sign_adjust(&qq, &q[i]);
        }
        if t % cfg.record_every == 0 || t == cfg.t_o {
            trace.push(IterRecord {
                outer: t,
                total_iters: total,
                error: average_error(&setting.truth, &q),
                p2p_avg: net.counters.avg(),
            });
        }
        if t == cfg.t_o {
            break;
        }
        // Gradient-tracking update.
        let grads: Vec<Mat> = (0..n).map(|i| setting.covs[i].apply(&q[i])).collect();
        for i in 0..n {
            s[i].axpy(1.0, &grads[i]);
            s[i].axpy(-1.0, &prev_grad[i]);
        }
        prev_grad = grads;
        fastmix(net, &mut s, cfg.mix_rounds, eta);
        total += cfg.mix_rounds;
    }
    (q, trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::spectrum::Spectrum;
    use crate::data::synthetic::SyntheticDataset;
    use crate::graph::Graph;
    use crate::metrics::subspace::subspace_error;
    use crate::util::rng::Rng;

    fn setting(seed: u64) -> (SampleSetting, Rng) {
        let mut rng = Rng::new(seed);
        let spec = Spectrum::with_gap(16, 3, 0.5);
        let ds = SyntheticDataset::full(&spec, 800, 6, &mut rng);
        let s = SampleSetting::from_parts(&ds.parts, 3, &mut rng);
        (s, rng)
    }

    #[test]
    fn deepca_converges_to_truth() {
        let (s, mut rng) = setting(1);
        let g = Graph::erdos_renyi(6, 0.6, &mut rng);
        let mut net = SyncNetwork::new(g);
        let (q, _) = run_deepca(&mut net, &s, &DeepcaConfig { mix_rounds: 8, t_o: 120, record_every: 5 });
        for qi in &q {
            let e = subspace_error(&s.truth, qi);
            assert!(e < 1e-6, "err={e}");
        }
    }

    #[test]
    fn deepca_uses_fewer_messages_than_sdot_for_same_error() {
        // Remark 1: DeEPCA saves the log factor in communications.
        use crate::algorithms::sdot::{run_sdot, SdotConfig};
        use crate::consensus::schedule::Schedule;

        let (s, mut rng) = setting(2);
        let g = Graph::erdos_renyi(6, 0.6, &mut rng);

        let mut net1 = SyncNetwork::new(g.clone());
        let (_, tr_sdot) = run_sdot(&mut net1, &s, &SdotConfig::new(Schedule::fixed(50), 120));

        let mut net2 = SyncNetwork::new(g);
        let (_, tr_deepca) =
            run_deepca(&mut net2, &s, &DeepcaConfig { mix_rounds: 8, t_o: 120, record_every: 1 });

        let tol = 1e-6;
        let p2p_at = |tr: &crate::metrics::trace::RunTrace| {
            tr.records.iter().find(|r| r.error <= tol).map(|r| r.p2p_avg)
        };
        let a = p2p_at(&tr_sdot).expect("sdot reaches tol");
        let b = p2p_at(&tr_deepca).expect("deepca reaches tol");
        assert!(b < a, "deepca={b} sdot={a}");
    }

    #[test]
    fn fastmix_beats_plain_consensus() {
        let mut rng = Rng::new(3);
        let g = Graph::ring(12);
        let z0: Vec<Mat> = (0..12).map(|_| Mat::gauss(4, 2, &mut rng)).collect();
        let avg = crate::consensus::engine::exact_average(&z0);
        let sigma = slem(&crate::consensus::weights::local_degree_weights(&g));
        let root = (1.0 - sigma * sigma).sqrt();
        let eta = (1.0 - root) / (1.0 + root);

        let rounds = 30;
        let mut plain = z0.clone();
        let mut net1 = SyncNetwork::new(g.clone());
        net1.consensus(&mut plain, rounds);
        let err_plain: f64 = plain.iter().map(|m| m.dist_fro(&avg)).fold(0.0, f64::max);

        let mut fast = z0.clone();
        let mut net2 = SyncNetwork::new(g);
        fastmix(&mut net2, &mut fast, rounds, eta);
        let err_fast: f64 = fast.iter().map(|m| m.dist_fro(&avg)).fold(0.0, f64::max);

        assert!(err_fast < err_plain * 0.5, "fast={err_fast} plain={err_plain}");
    }
}
