//! DeEPCA — Decentralized Exact PCA with gradient tracking [27].
//!
//! The strongest distributed competitor in the paper's comparisons
//! (Remark 1: same algorithmic complexity as S-DOT, one log factor better
//! in communications). Each node tracks the network-average power-iteration
//! direction with a gradient-tracking recursion and runs a few **FastMix**
//! (Chebyshev-accelerated consensus) rounds per outer iteration:
//!
//! ```text
//! S_i ← FastMix( S_i + M_i Q_i^{t} − M_i Q_i^{t-1} )
//! Q_i^{t+1} = SignAdjust( QR(S_i) , Q_i^{t} )
//! ```
//!
//! The sign adjustment keeps the per-column orientation consistent across
//! iterations so the tracking differences stay meaningful.

use super::common::SampleSetting;
use crate::consensus::mixing::slem;
use crate::linalg::qr::{self, qr_policy_into};
use crate::linalg::svd::sign_adjust_into;
use crate::linalg::Mat;
use crate::metrics::subspace::average_error;
use crate::metrics::trace::{IterRecord, RunTrace};
use crate::network::sim::SyncNetwork;
use crate::runtime::pool::DisjointSlice;
use crate::runtime::workspace::{node_scratch, NodeScratch};

#[derive(Clone, Copy, Debug)]
pub struct DeepcaConfig {
    /// FastMix rounds per outer iteration (the paper's K; small, e.g. 3–8).
    pub mix_rounds: usize,
    pub t_o: usize,
    pub record_every: usize,
}

impl DeepcaConfig {
    pub fn new(t_o: usize) -> DeepcaConfig {
        DeepcaConfig { mix_rounds: 5, t_o, record_every: 1 }
    }
}

/// Chebyshev-accelerated consensus (FastMix). One round costs one neighbor
/// exchange, like plain consensus, but the two-term recursion contracts at
/// `(1−√(1−σ²))/(1+√(1−σ²))` per round instead of σ.
///
/// The allocating entry point delegates to [`fastmix_ws`], which reuses
/// caller-provided `prev`/`wx` buffers (the zero-allocation path);
/// `run_deepca` calls the workspace variant directly, so this wrapper
/// only backs the FastMix unit test.
#[cfg(test)]
fn fastmix(net: &mut SyncNetwork, z: &mut Vec<Mat>, rounds: usize, eta: f64) {
    let mut prev = vec![Mat::zeros(0, 0); z.len()];
    let mut wx = vec![Mat::zeros(0, 0); z.len()];
    fastmix_ws(net, z, rounds, eta, &mut prev, &mut wx);
}

fn fastmix_ws(
    net: &mut SyncNetwork,
    z: &mut Vec<Mat>,
    rounds: usize,
    eta: f64,
    prev: &mut [Mat],
    wx: &mut Vec<Mat>,
) {
    if rounds == 0 {
        return;
    }
    for (p, zi) in prev.iter_mut().zip(z.iter()) {
        p.copy_from(zi);
    }
    // First round: plain mixing.
    net.consensus(z, 1);
    for _ in 1..rounds {
        // x^{k+1} = (1+η) W x^k − η x^{k-1}
        for (w, zi) in wx.iter_mut().zip(z.iter()) {
            w.copy_from(zi);
        }
        net.consensus(wx, 1);
        for i in 0..z.len() {
            wx[i].scale_inplace(1.0 + eta);
            wx[i].axpy(-eta, &prev[i]);
            // prev ← x^k, z ← x^{k+1}; old z buffer becomes next wx.
            std::mem::swap(&mut prev[i], &mut z[i]);
            std::mem::swap(&mut z[i], &mut wx[i]);
        }
    }
}

pub fn run_deepca(
    net: &mut SyncNetwork,
    setting: &SampleSetting,
    cfg: &DeepcaConfig,
) -> (Vec<Mat>, RunTrace) {
    let n = net.n();
    // SLEM needs the dense eigendecomposition — a one-off O(n³) setup
    // computation on the same Metropolis weights the network mixes with.
    let sigma = slem(&net.weights().to_dense()).min(0.999_999);
    let root = (1.0 - sigma * sigma).sqrt();
    let eta = (1.0 - root) / (1.0 + root);

    let mut q: Vec<Mat> = vec![setting.q_init.clone(); n];
    let mut prev_grad: Vec<Mat> = (0..n).map(|i| setting.covs[i].apply(&q[i])).collect();
    // Tracker initialized at the local gradient, then mixed once.
    let mut s: Vec<Mat> = prev_grad.clone();
    // Persistent workspace: FastMix double buffers, gradients, per-node
    // QR/sign scratch.
    let mut fm_prev = vec![Mat::zeros(0, 0); n];
    let mut fm_wx = vec![Mat::zeros(0, 0); n];
    let mut grads = vec![Mat::zeros(0, 0); n];
    let mut scratch: Vec<NodeScratch> = node_scratch(n);
    fastmix_ws(net, &mut s, cfg.mix_rounds, eta, &mut fm_prev, &mut fm_wx);

    let mut trace = RunTrace::new("DeEPCA");
    let mut total = cfg.mix_rounds;
    // Step-12 kernel: snapshot the process-wide `--qr` policy once.
    let qr_policy = qr::default_qr_policy();

    for t in 1..=cfg.t_o {
        // Orthonormalize the tracker with sign consistency, node-parallel.
        {
            let qs = DisjointSlice::new(q.as_mut_slice());
            let scr = DisjointSlice::new(scratch.as_mut_slice());
            let sref = &s;
            net.pool().run_chunks(n, &|lo, hi| {
                for i in lo..hi {
                    // SAFETY: index i belongs to exactly one chunk.
                    let (qi, sc) = unsafe { (qs.get_mut(i), scr.get_mut(i)) };
                    qr_policy_into(&sref[i], &mut sc.t0, None, &mut sc.qr, qr_policy);
                    sign_adjust_into(&sc.t0, qi, &mut sc.t1, &mut sc.t2);
                    std::mem::swap(qi, &mut sc.t1);
                }
            });
        }
        if t % cfg.record_every == 0 || t == cfg.t_o {
            trace.push(IterRecord {
                outer: t,
                total_iters: total,
                error: average_error(&setting.truth, &q),
                p2p_avg: net.counters.avg(),
            });
        }
        if t == cfg.t_o {
            break;
        }
        // Gradient-tracking update, node-parallel.
        {
            let gs = DisjointSlice::new(grads.as_mut_slice());
            let scr = DisjointSlice::new(scratch.as_mut_slice());
            let qref = &q;
            let covs = &setting.covs;
            net.pool().run_chunks(n, &|lo, hi| {
                for i in lo..hi {
                    // SAFETY: index i belongs to exactly one chunk.
                    let (g, sc) = unsafe { (gs.get_mut(i), scr.get_mut(i)) };
                    covs[i].apply_into(&qref[i], g, &mut sc.t0);
                }
            });
        }
        for i in 0..n {
            s[i].axpy(1.0, &grads[i]);
            s[i].axpy(-1.0, &prev_grad[i]);
            std::mem::swap(&mut prev_grad[i], &mut grads[i]);
        }
        fastmix_ws(net, &mut s, cfg.mix_rounds, eta, &mut fm_prev, &mut fm_wx);
        total += cfg.mix_rounds;
    }
    (q, trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::spectrum::Spectrum;
    use crate::data::synthetic::SyntheticDataset;
    use crate::graph::Graph;
    use crate::metrics::subspace::subspace_error;
    use crate::util::rng::Rng;

    fn setting(seed: u64) -> (SampleSetting, Rng) {
        let mut rng = Rng::new(seed);
        let spec = Spectrum::with_gap(16, 3, 0.5);
        let ds = SyntheticDataset::full(&spec, 800, 6, &mut rng);
        let s = SampleSetting::from_parts(&ds.parts, 3, &mut rng);
        (s, rng)
    }

    #[test]
    fn deepca_converges_to_truth() {
        let (s, mut rng) = setting(1);
        let g = Graph::erdos_renyi(6, 0.6, &mut rng);
        let mut net = SyncNetwork::new(g);
        let (q, _) = run_deepca(&mut net, &s, &DeepcaConfig { mix_rounds: 8, t_o: 120, record_every: 5 });
        for qi in &q {
            let e = subspace_error(&s.truth, qi);
            assert!(e < 1e-6, "err={e}");
        }
    }

    #[test]
    fn deepca_uses_fewer_messages_than_sdot_for_same_error() {
        // Remark 1: DeEPCA saves the log factor in communications.
        use crate::algorithms::sdot::{run_sdot, SdotConfig};
        use crate::consensus::schedule::Schedule;

        let (s, mut rng) = setting(2);
        let g = Graph::erdos_renyi(6, 0.6, &mut rng);

        let mut net1 = SyncNetwork::new(g.clone());
        let (_, tr_sdot) = run_sdot(&mut net1, &s, &SdotConfig::new(Schedule::fixed(50), 120));

        let mut net2 = SyncNetwork::new(g);
        let (_, tr_deepca) =
            run_deepca(&mut net2, &s, &DeepcaConfig { mix_rounds: 8, t_o: 120, record_every: 1 });

        let tol = 1e-6;
        let p2p_at = |tr: &crate::metrics::trace::RunTrace| {
            tr.records.iter().find(|r| r.error <= tol).map(|r| r.p2p_avg)
        };
        let a = p2p_at(&tr_sdot).expect("sdot reaches tol");
        let b = p2p_at(&tr_deepca).expect("deepca reaches tol");
        assert!(b < a, "deepca={b} sdot={a}");
    }

    #[test]
    fn fastmix_beats_plain_consensus() {
        let mut rng = Rng::new(3);
        let g = Graph::ring(12);
        let z0: Vec<Mat> = (0..12).map(|_| Mat::gauss(4, 2, &mut rng)).collect();
        let avg = crate::consensus::engine::exact_average(&z0);
        let sigma = slem(&crate::consensus::weights::local_degree_weights(&g));
        let root = (1.0 - sigma * sigma).sqrt();
        let eta = (1.0 - root) / (1.0 + root);

        let rounds = 30;
        let mut plain = z0.clone();
        let mut net1 = SyncNetwork::new(g.clone());
        net1.consensus(&mut plain, rounds);
        let err_plain: f64 = plain.iter().map(|m| m.dist_fro(&avg)).fold(0.0, f64::max);

        let mut fast = z0.clone();
        let mut net2 = SyncNetwork::new(g);
        fastmix(&mut net2, &mut fast, rounds, eta);
        let err_fast: f64 = fast.iter().map(|m| m.dist_fro(&avg)).fold(0.0, f64::max);

        assert!(err_fast < err_plain * 0.5, "fast={err_fast} plain={err_plain}");
    }
}
