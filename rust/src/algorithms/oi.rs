//! Centralized baselines: orthogonal iteration (OI) and the sequential
//! power method (SeqPM).
//!
//! OI estimates the whole r-dimensional subspace at once; SeqPM estimates
//! the basis vectors one at a time with Hotelling deflation. The paper uses
//! both as the centralized reference curves in Figures 4–6 and 8/10 — for
//! them "total iterations" equals the outer count (no consensus inner loop).

use super::common::SampleSetting;
use crate::linalg::qr;
use crate::linalg::Mat;
use crate::metrics::subspace::subspace_error;
use crate::metrics::trace::{IterRecord, RunTrace};

/// Centralized orthogonal iteration on `M = Σ_i M_i`.
///
/// The numerical loop reuses a persistent workspace (`v`, per-term
/// scratch, QR scratch); only trace recording allocates.
pub fn run_oi(setting: &SampleSetting, t_o: usize) -> (Mat, RunTrace) {
    let mut q = setting.q_init.clone();
    let mut trace = RunTrace::new("OI");
    let mut v = Mat::zeros(0, 0);
    let mut tmp = Mat::zeros(0, 0);
    let mut tmp2 = Mat::zeros(0, 0);
    let mut qnext = Mat::zeros(0, 0);
    let mut ws = qr::QrScratch::new();
    let qr_policy = qr::default_qr_policy();
    for t in 1..=t_o {
        setting.global_apply_into(&q, &mut v, &mut tmp, &mut tmp2);
        qr::orthonormalize_policy_into(&v, &mut qnext, &mut ws, qr_policy);
        std::mem::swap(&mut q, &mut qnext);
        trace.push(IterRecord {
            outer: t,
            total_iters: t,
            error: subspace_error(&setting.truth, &q),
            p2p_avg: 0.0,
        });
    }
    (q, trace)
}

/// Centralized sequential power method with deflation: vector j is driven
/// by `(M − Σ_{k<j} λ_k q_k q_kᵀ)`, each for `iters_per_vec` iterations.
/// The error trace scores the full current estimate matrix — columns not
/// yet estimated sit at their initial values, which is why the error stays
/// high until the last vector converges (the effect the paper highlights).
pub fn run_seqpm(setting: &SampleSetting, iters_per_vec: usize) -> (Mat, RunTrace) {
    let r = setting.r;
    let mut q = setting.q_init.clone();
    let mut trace = RunTrace::new("SeqPM");
    let mut lambdas: Vec<f64> = Vec::with_capacity(r);
    let mut done: Vec<Vec<f64>> = Vec::with_capacity(r);
    let mut total = 0usize;
    // Metric-side orthonormalization: `--qr` kernel, reused workspace.
    let qr_policy = qr::default_qr_policy();
    let mut qws = qr::QrScratch::new();
    let mut qhat = Mat::zeros(0, 0);

    for j in 0..r {
        let mut v: Vec<f64> = q.col(j);
        normalize(&mut v);
        for _ in 0..iters_per_vec {
            // w = M v − Σ_k λ_k q_k (q_kᵀ v)
            let vm = Mat::from_vec(v.len(), 1, v.clone());
            let mut w = setting.global_apply(&vm).col(0);
            for (k, qk) in done.iter().enumerate() {
                let dot = dotv(qk, &v);
                for (wi, qi) in w.iter_mut().zip(qk.iter()) {
                    *wi -= lambdas[k] * dot * qi;
                }
            }
            normalize(&mut w);
            v = w;
            total += 1;
            q.set_col(j, &v);
            qr::orthonormalize_policy_into(&q, &mut qhat, &mut qws, qr_policy);
            trace.push(IterRecord {
                outer: total,
                total_iters: total,
                error: subspace_error(&setting.truth, &qhat),
                p2p_avg: 0.0,
            });
        }
        // Rayleigh quotient for the deflation weight.
        let vm = Mat::from_vec(v.len(), 1, v.clone());
        let mv = setting.global_apply(&vm).col(0);
        lambdas.push(dotv(&v, &mv));
        done.push(v);
    }
    // Reuse the warm metric workspace for the final estimate (also
    // covers iters_per_vec == 0, where the loop never filled qhat).
    qr::orthonormalize_policy_into(&q, &mut qhat, &mut qws, qr_policy);
    (qhat, trace)
}

fn dotv(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b.iter()).map(|(x, y)| x * y).sum()
}

fn normalize(v: &mut [f64]) {
    let n = v.iter().map(|x| x * x).sum::<f64>().sqrt();
    if n > 0.0 {
        for x in v.iter_mut() {
            *x /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::spectrum::Spectrum;
    use crate::data::synthetic::SyntheticDataset;
    use crate::util::rng::Rng;

    fn setting(seed: u64, gap: f64) -> SampleSetting {
        let mut rng = Rng::new(seed);
        let spec = Spectrum::with_gap(20, 5, gap);
        let ds = SyntheticDataset::full(&spec, 500, 5, &mut rng);
        SampleSetting::from_parts(&ds.parts, 5, &mut rng)
    }

    #[test]
    fn oi_converges_linearly() {
        let s = setting(1, 0.5);
        let (q, trace) = run_oi(&s, 60);
        assert!(subspace_error(&s.truth, &q) < 1e-12);
        // Error after 2k iterations should be ≲ gap^k-ish: strictly smaller.
        let e10 = trace.records[9].error;
        let e30 = trace.records[29].error;
        assert!(e30 < e10 * 1e-3, "e10={e10} e30={e30}");
    }

    #[test]
    fn seqpm_converges_eventually() {
        let s = setting(2, 0.5);
        let (q, trace) = run_seqpm(&s, 150);
        assert!(subspace_error(&s.truth, &q) < 1e-6, "err={}", subspace_error(&s.truth, &q));
        assert_eq!(trace.records.len(), 5 * 150);
    }

    #[test]
    fn seqpm_error_stays_high_until_last_vector() {
        // The paper's observation: sequential estimation keeps overall
        // subspace error large until the final vector is being estimated.
        let s = setting(3, 0.5);
        let (_, trace) = run_seqpm(&s, 100);
        let mid = trace.records[249].error; // after 2.5 of 5 vectors
        let end = trace.final_error();
        assert!(mid > 10.0 * end.max(1e-14), "mid={mid} end={end}");
    }

    #[test]
    fn oi_beats_seqpm_in_iterations() {
        let s = setting(4, 0.5);
        let (_, tr_oi) = run_oi(&s, 500);
        let (_, tr_seq) = run_seqpm(&s, 100);
        let tol = 1e-5;
        let oi_iters = tr_oi.iters_to_error(tol);
        let seq_iters = tr_seq.iters_to_error(tol);
        assert!(oi_iters.is_some());
        match (oi_iters, seq_iters) {
            (Some(a), Some(b)) => assert!(a < b, "oi={a} seq={b}"),
            (Some(_), None) => {} // SeqPM never got there — also fine.
            _ => panic!("unexpected"),
        }
    }
}
