//! SeqDistPM — sequential distributed power method.
//!
//! The distributed counterpart of SeqPM ([13]-style): the r basis vectors
//! are estimated one at a time; each power iteration computes the local
//! deflated product, consensus-averages it across the network (with
//! rescaling to a sum estimate), and normalizes. Deflation weights λ_k are
//! Rayleigh quotients computed once per finished vector via one extra
//! consensus round (its messages are counted too).

use super::common::SampleSetting;
use crate::linalg::qr;
use crate::linalg::Mat;
use crate::metrics::subspace::average_error;
use crate::metrics::trace::{IterRecord, RunTrace};
use crate::network::sim::SyncNetwork;

/// Configuration: `iters_per_vec` power iterations per basis vector, each
/// with `t_c` consensus rounds.
#[derive(Clone, Copy, Debug)]
pub struct SeqDistPmConfig {
    pub iters_per_vec: usize,
    pub t_c: usize,
    pub record_every: usize,
}

pub fn run_seqdistpm(
    net: &mut SyncNetwork,
    setting: &SampleSetting,
    cfg: &SeqDistPmConfig,
) -> (Vec<Mat>, RunTrace) {
    let n = net.n();
    let d = setting.d();
    let r = setting.r;
    let mut trace = RunTrace::new("SeqDistPM");
    // Per-node running estimate matrix (starts at the common init).
    let mut q: Vec<Mat> = vec![setting.q_init.clone(); n];
    // Finished vectors and deflation weights, agreed across nodes.
    let mut lambdas: Vec<Vec<f64>> = vec![Vec::new(); n];
    let mut total = 0usize;
    let mut outer = 0usize;
    // Persistent workspace: working vectors, deflated products, scalar
    // consensus payloads, and per-node apply scratch.
    let mut v: Vec<Mat> = vec![Mat::zeros(d, 1); n];
    let mut z: Vec<Mat> = vec![Mat::zeros(0, 0); n];
    let mut lam: Vec<Mat> = vec![Mat::zeros(1, 1); n];
    let mut tmp: Vec<Mat> = vec![Mat::zeros(0, 0); n];
    // Metric/final orthonormalization: the `--qr` kernel, snapshotted.
    let qr_policy = qr::default_qr_policy();

    for j in 0..r {
        // Current working vector at each node.
        for i in 0..n {
            v[i].reshape_in_place(d, 1);
            for row in 0..d {
                v[i].data[row] = q[i].get(row, j);
            }
            normalize(&mut v[i].data);
        }
        for it in 0..cfg.iters_per_vec {
            // Local deflated product.
            for i in 0..n {
                setting.covs[i].apply_into(&v[i], &mut z[i], &mut tmp[i]);
                // Deflate with the previously agreed vectors: the local
                // share of λ_k q_k q_kᵀ v is split evenly (1/N each) so
                // the consensus sum reconstructs the full deflation.
                for k in 0..lambdas[i].len() {
                    let dot = q[i].col_dot(k, &v[i].data);
                    let coeff = lambdas[i][k] * dot / n as f64;
                    for (row, wi) in z[i].data.iter_mut().enumerate() {
                        *wi -= coeff * q[i].get(row, k);
                    }
                }
            }
            net.consensus_sum(&mut z, cfg.t_c);
            total += cfg.t_c;
            outer += 1;
            for i in 0..n {
                normalize(&mut z[i].data);
                q[i].set_col(j, &z[i].data);
                v[i].copy_from(&z[i]);
            }
            if outer % cfg.record_every == 0 || (j == r - 1 && it == cfg.iters_per_vec - 1) {
                let estimates: Vec<Mat> =
                    q.iter().map(|qi| qr::orthonormalize_policy(qi, qr_policy)).collect();
                trace.push(IterRecord {
                    outer,
                    total_iters: total,
                    error: average_error(&setting.truth, &estimates),
                    p2p_avg: net.counters.avg(),
                });
            }
        }
        // Agree on λ_j = vᵀ M v via one consensus round over local scalars.
        for i in 0..n {
            setting.covs[i].apply_into(&v[i], &mut z[i], &mut tmp[i]);
            lam[i].reshape_in_place(1, 1);
            lam[i].data[0] = dotv(&v[i].data, &z[i].data);
        }
        net.consensus_sum(&mut lam, cfg.t_c);
        total += cfg.t_c;
        for i in 0..n {
            lambdas[i].push(lam[i].get(0, 0));
        }
    }
    let qfinal: Vec<Mat> = q.iter().map(|qi| qr::orthonormalize_policy(qi, qr_policy)).collect();
    (qfinal, trace)
}

fn dotv(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b.iter()).map(|(x, y)| x * y).sum()
}

fn normalize(v: &mut [f64]) {
    let n = v.iter().map(|x| x * x).sum::<f64>().sqrt();
    if n > 0.0 {
        for x in v.iter_mut() {
            *x /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::spectrum::Spectrum;
    use crate::data::synthetic::SyntheticDataset;
    use crate::graph::Graph;
    use crate::metrics::subspace::subspace_error;
    use crate::util::rng::Rng;

    fn setting(seed: u64) -> (SampleSetting, Rng) {
        let mut rng = Rng::new(seed);
        // SeqDistPM needs distinct eigenvalues (power-method requirement).
        let spec = Spectrum::with_gap(16, 3, 0.4);
        let ds = SyntheticDataset::full(&spec, 800, 6, &mut rng);
        let s = SampleSetting::from_parts(&ds.parts, 3, &mut rng);
        (s, rng)
    }

    #[test]
    fn seqdistpm_converges() {
        let (s, mut rng) = setting(1);
        let g = Graph::erdos_renyi(6, 0.6, &mut rng);
        let mut net = SyncNetwork::new(g);
        let cfg = SeqDistPmConfig { iters_per_vec: 120, t_c: 50, record_every: 10 };
        let (q, _) = run_seqdistpm(&mut net, &s, &cfg);
        for qi in &q {
            let e = subspace_error(&s.truth, qi);
            assert!(e < 1e-4, "err={e}");
        }
    }

    #[test]
    fn seqdistpm_nodes_agree() {
        let (s, mut rng) = setting(2);
        let g = Graph::erdos_renyi(6, 0.6, &mut rng);
        let mut net = SyncNetwork::new(g);
        let cfg = SeqDistPmConfig { iters_per_vec: 80, t_c: 50, record_every: 20 };
        let (q, _) = run_seqdistpm(&mut net, &s, &cfg);
        for i in 1..q.len() {
            assert!(subspace_error(&q[0], &q[i]) < 1e-6);
        }
    }

    #[test]
    fn seqdistpm_slower_than_sdot_in_total_iterations() {
        // Fig. 4's headline: simultaneous estimation (S-DOT) beats
        // sequential (SeqDistPM) on (inner × outer) iteration count.
        use crate::algorithms::sdot::{run_sdot, SdotConfig};
        use crate::consensus::schedule::Schedule;

        let (s, mut rng) = setting(3);
        let g = Graph::erdos_renyi(6, 0.6, &mut rng);

        let mut net1 = SyncNetwork::new(g.clone());
        let (_, tr_sdot) = run_sdot(&mut net1, &s, &SdotConfig::new(Schedule::fixed(50), 100));

        let mut net2 = SyncNetwork::new(g);
        let cfg = SeqDistPmConfig { iters_per_vec: 100, t_c: 50, record_every: 5 };
        let (_, tr_seq) = run_seqdistpm(&mut net2, &s, &cfg);

        let tol = 1e-4;
        let a = tr_sdot.iters_to_error(tol).expect("S-DOT reaches tol");
        match tr_seq.iters_to_error(tol) {
            Some(b) => assert!(a < b, "sdot={a} seqdistpm={b}"),
            None => {} // sequential never reached tolerance — consistent.
        }
    }
}
