//! S-DOT and SA-DOT (Algorithm 1) — the paper's core contribution for
//! sample-wise partitioned data.
//!
//! Two-scale iteration: every outer orthogonal iteration computes
//! `Z_i = M_i Q_i^{(t-1)}` locally, runs `T_c(t)` consensus-averaging rounds
//! over the network, rescales by `[W^{T_c} e_1]_i` to estimate the network
//! **sum** `Σ_j M_j Q_j`, and QR-orthonormalizes locally. S-DOT uses a
//! fixed `T_c`; SA-DOT grows it with `t` (Theorem 1 gives both linear
//! convergence to the true eigenspace of `M = Σ_i M_i`).

use super::common::SampleSetting;
use crate::consensus::schedule::Schedule;
use crate::linalg::qr::orthonormalize;
use crate::linalg::Mat;
use crate::metrics::subspace::{average_error, average_error_ws, SubspaceWs};
use crate::metrics::trace::{IterRecord, RunTrace};
use crate::network::sim::SyncNetwork;
use crate::runtime::pool::DisjointSlice;
use crate::runtime::qr_exec::{orthonormalize_nodes, QrFanScratch};
use crate::runtime::workspace::{node_scratch, MatRowsScratch, NodeScratch};
use crate::runtime::Backend;

/// Configuration for an S-DOT / SA-DOT run.
#[derive(Clone, Copy, Debug)]
pub struct SdotConfig {
    /// Consensus rounds per outer iteration.
    pub schedule: Schedule,
    /// Number of outer (orthogonal) iterations `T_o`.
    pub t_o: usize,
    /// Record a trace point every `record_every` outer iterations
    /// (1 = every iteration).
    pub record_every: usize,
}

impl SdotConfig {
    pub fn new(schedule: Schedule, t_o: usize) -> SdotConfig {
        SdotConfig { schedule, t_o, record_every: 1 }
    }
}

/// A resumable Algorithm-1 run with a persistent workspace.
///
/// All per-iteration buffers — the `Z_i` products, the `XᵀQ`
/// intermediates, the per-node QR scratch, the trace (pre-reserved from
/// `t_o / record_every`), the subspace-metric workspace, and (inside
/// `SyncNetwork`) the consensus double buffer — are allocated at
/// construction and reused by every [`SdotRun::step`], so steady-state
/// outer iterations perform zero heap allocations even at
/// `record_every = 1` (verified by `bench_hotpath`'s counting
/// allocator). Per-node work (step 5's `M_i Q`) fans out across the
/// network's pool **hierarchically** — node chunks first, then rows of
/// each node's product when threads are left over. Step 12's local QR is
/// policy-dispatched through the backend's [`QrPolicy`]: Householder and
/// blocked run node-parallel (sequential per node), while the TSQR
/// policy fans each node's fixed row-block leaves across the pool too
/// (`runtime::qr_exec`), so even N < threads keeps every core busy.
/// Results are bitwise deterministic for any thread count under every
/// policy.
///
/// [`QrPolicy`]: crate::linalg::qr::QrPolicy
pub struct SdotRun<'a> {
    net: &'a mut SyncNetwork,
    setting: &'a SampleSetting,
    cfg: SdotConfig,
    backend: &'a dyn Backend,
    q: Vec<Mat>,
    z: Vec<Mat>,
    /// Per-node phase-A intermediates (`XᵀQ`; `0 × r` for dense covs).
    tmp: Vec<Mat>,
    scratch: Vec<NodeScratch>,
    /// Raw-view table for the hierarchical dispatches (reused, no alloc).
    view_scratch: MatRowsScratch,
    /// TSQR (node × leaf) fan-out workspace for step 12 (reused, no alloc).
    qr_fan: QrFanScratch,
    metric_ws: SubspaceWs,
    trace: RunTrace,
    t: usize,
    total_iters: usize,
}

impl<'a> SdotRun<'a> {
    pub fn new(
        net: &'a mut SyncNetwork,
        setting: &'a SampleSetting,
        cfg: &SdotConfig,
        backend: &'a dyn Backend,
    ) -> SdotRun<'a> {
        let n = net.n();
        assert_eq!(setting.n_nodes(), n, "setting/network size mismatch");
        let d = setting.d();
        let r = setting.q_init.cols;
        let records = cfg.t_o / cfg.record_every.max(1) + 2;
        SdotRun {
            net,
            setting,
            cfg: *cfg,
            backend,
            q: vec![setting.q_init.clone(); n],
            z: (0..n).map(|_| Mat::zeros(d, r)).collect(),
            // Phase-A intermediates are only used by row-split backends;
            // opaque backends route `XᵀQ` through `scratch[i].t0` (which
            // is lazily sized on first use), so don't double-allocate.
            tmp: if backend.supports_row_split() {
                setting.covs.iter().map(|c| Mat::zeros(c.tmp_rows(), r)).collect()
            } else {
                (0..n).map(|_| Mat::zeros(0, r)).collect()
            },
            scratch: node_scratch(n),
            view_scratch: MatRowsScratch::new(),
            qr_fan: QrFanScratch::new(),
            metric_ws: SubspaceWs::new(),
            trace: RunTrace::with_capacity("S-DOT", records),
            t: 0,
            total_iters: 0,
        }
    }

    /// Current per-node estimates.
    pub fn estimates(&self) -> &[Mat] {
        &self.q
    }

    /// Outer iterations completed so far.
    pub fn outer(&self) -> usize {
        self.t
    }

    /// One outer orthogonal iteration (Alg. 1 steps 5–12).
    pub fn step(&mut self) {
        let n = self.q.len();
        self.t += 1;
        let t = self.t;
        // Step 5: local products (the per-node hot path). Row-split
        // backends run it as two hierarchical phases — phase A fills the
        // `XᵀQ` intermediates, phase B the `M_i Q` rows — so when the
        // pool has more threads than nodes the leftover threads split
        // each node's rows (bitwise identical to the single-dispatch
        // path; the kernels are exact row restrictions). Opaque backends
        // keep the node-level dispatch.
        if self.backend.supports_row_split() {
            let q = &self.q;
            let covs = &self.setting.covs;
            let backend = self.backend;
            // Phase A only exists for implicit (sample-held) operators;
            // dense tables skip the dispatch entirely.
            if covs.iter().any(|c| c.tmp_rows() > 0) {
                let tmps = self.view_scratch.fill(self.tmp.as_mut_slice());
                self.net.pool().run_chunks2(n, &|i| covs[i].tmp_rows(), &|i, lo, hi| {
                    // SAFETY: rows [lo, hi) of tmp[i] belong to one task.
                    let ti = unsafe { tmps.rows_mut(i, lo, hi) };
                    backend.cov_apply_tmp_rows(&covs[i], &q[i], lo, hi, ti);
                });
            }
            {
                let zs = self.view_scratch.fill(self.z.as_mut_slice());
                let tmp = &self.tmp;
                let d = self.setting.d();
                self.net.pool().run_chunks2(n, &|_| d, &|i, lo, hi| {
                    // SAFETY: rows [lo, hi) of z[i] belong to one task.
                    let zi = unsafe { zs.rows_mut(i, lo, hi) };
                    backend.cov_apply_out_rows(&covs[i], &q[i], &tmp[i], lo, hi, zi);
                });
            }
        } else {
            let zs = DisjointSlice::new(self.z.as_mut_slice());
            let scr = DisjointSlice::new(self.scratch.as_mut_slice());
            let q = &self.q;
            let covs = &self.setting.covs;
            let backend = self.backend;
            self.net.pool().run_chunks(n, &|lo, hi| {
                for i in lo..hi {
                    // SAFETY: index i belongs to exactly one chunk.
                    let (zi, si) = unsafe { (zs.get_mut(i), scr.get_mut(i)) };
                    backend.cov_apply_into(&covs[i], &q[i], zi, &mut si.t0);
                }
            });
        }
        // Steps 6–11: consensus + rescale to a sum estimate.
        let rounds = self.cfg.schedule.rounds_at(t);
        self.net.consensus_sum(&mut self.z, rounds);
        self.total_iters += rounds;
        // Step 12: local QR through the policy executor — node-parallel
        // for Householder/blocked, (node × leaf) fan-out for TSQR.
        orthonormalize_nodes(
            self.net.pool(),
            self.backend,
            &self.z,
            &mut self.q,
            &mut self.scratch,
            &mut self.qr_fan,
            &mut self.view_scratch,
        );
        if t % self.cfg.record_every == 0 || t == self.cfg.t_o {
            self.trace.push(IterRecord {
                outer: t,
                total_iters: self.total_iters,
                error: average_error_ws(&self.setting.truth, &self.q, &mut self.metric_ws),
                p2p_avg: self.net.counters.avg(),
            });
        }
    }

    /// Consume the run, returning estimates and trace.
    pub fn finish(self) -> (Vec<Mat>, RunTrace) {
        (self.q, self.trace)
    }
}

/// Run Algorithm 1 on the given network. Returns the per-node estimates and
/// the per-iteration trace. The `backend` computes the `M_i Q` hot path
/// (native Rust or the AOT-compiled XLA artifact).
pub fn run_sdot_with_backend(
    net: &mut SyncNetwork,
    setting: &SampleSetting,
    cfg: &SdotConfig,
    backend: &dyn Backend,
) -> (Vec<Mat>, RunTrace) {
    let mut run = SdotRun::new(net, setting, cfg, backend);
    for _ in 0..cfg.t_o {
        run.step();
    }
    run.finish()
}

/// S-DOT with the native backend (the common path for experiments). The
/// backend snapshots the process-wide `--qr` policy at this call.
pub fn run_sdot(
    net: &mut SyncNetwork,
    setting: &SampleSetting,
    cfg: &SdotConfig,
) -> (Vec<Mat>, RunTrace) {
    run_sdot_with_backend(net, setting, cfg, &crate::runtime::NativeBackend::default())
}

/// SA-DOT is S-DOT with an adaptive schedule; this wrapper labels the trace.
pub fn run_sadot(
    net: &mut SyncNetwork,
    setting: &SampleSetting,
    cfg: &SdotConfig,
) -> (Vec<Mat>, RunTrace) {
    assert!(
        matches!(cfg.schedule, Schedule::Adaptive { .. }),
        "SA-DOT requires an adaptive schedule"
    );
    let (q, mut trace) = run_sdot(net, setting, cfg);
    trace.algorithm = "SA-DOT".into();
    (q, trace)
}

/// Reference: exact-averaging S-DOT (T_c → ∞ limit). With perfect
/// consensus every node performs centralized OI — used by tests.
pub fn run_sdot_exact_consensus(
    setting: &SampleSetting,
    t_o: usize,
) -> (Mat, RunTrace) {
    let mut q = setting.q_init.clone();
    let mut trace = RunTrace::new("S-DOT(exact)");
    for t in 1..=t_o {
        let v = setting.global_apply(&q);
        q = orthonormalize(&v);
        trace.push(IterRecord {
            outer: t,
            total_iters: t,
            error: average_error(&setting.truth, std::slice::from_ref(&q)),
            p2p_avg: 0.0,
        });
    }
    (q, trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::spectrum::Spectrum;
    use crate::data::synthetic::SyntheticDataset;
    use crate::graph::Graph;
    use crate::metrics::subspace::subspace_error;
    use crate::util::rng::Rng;

    fn setting(seed: u64, d: usize, r: usize, gap: f64, nodes: usize) -> (SampleSetting, Rng) {
        let mut rng = Rng::new(seed);
        let spec = Spectrum::with_gap(d, r, gap);
        let ds = SyntheticDataset::full(&spec, 500, nodes, &mut rng);
        let s = SampleSetting::from_parts(&ds.parts, r, &mut rng);
        (s, rng)
    }

    #[test]
    fn sdot_converges_to_truth() {
        let (s, mut rng) = setting(1, 20, 5, 0.7, 10);
        let g = Graph::erdos_renyi(10, 0.5, &mut rng);
        let mut net = SyncNetwork::new(g);
        let cfg = SdotConfig::new(Schedule::fixed(50), 60);
        let (q, trace) = run_sdot(&mut net, &s, &cfg);
        for qi in &q {
            // Finite T_c leaves a consensus-accuracy error floor (Theorem 1's
            // ε^{T_o} term); 1e-6 is far below any plotted value in Fig. 1.
            assert!(subspace_error(&s.truth, qi) < 1e-6, "err={}", subspace_error(&s.truth, qi));
        }
        assert!(trace.final_error() < 1e-6);
    }

    #[test]
    fn sdot_nodes_reach_consensus() {
        let (s, mut rng) = setting(2, 20, 5, 0.7, 8);
        let g = Graph::erdos_renyi(8, 0.4, &mut rng);
        let mut net = SyncNetwork::new(g);
        let cfg = SdotConfig::new(Schedule::fixed(50), 50);
        let (q, _) = run_sdot(&mut net, &s, &cfg);
        for i in 1..8 {
            // Same subspace at every node.
            assert!(subspace_error(&q[0], &q[i]) < 1e-8);
        }
    }

    #[test]
    fn sadot_converges_with_adaptive_schedule() {
        let (s, mut rng) = setting(3, 20, 5, 0.7, 10);
        let g = Graph::erdos_renyi(10, 0.5, &mut rng);
        let mut net = SyncNetwork::new(g);
        let cfg = SdotConfig::new(Schedule::adaptive(1.0, 1, 50), 80);
        let (q, trace) = run_sadot(&mut net, &s, &cfg);
        assert_eq!(trace.algorithm, "SA-DOT");
        for qi in &q {
            assert!(subspace_error(&s.truth, qi) < 1e-6);
        }
    }

    #[test]
    fn sadot_uses_fewer_messages_than_sdot() {
        let (s, mut rng) = setting(4, 20, 5, 0.7, 10);
        let g = Graph::erdos_renyi(10, 0.5, &mut rng);

        let mut net1 = SyncNetwork::new(g.clone());
        let cfg1 = SdotConfig::new(Schedule::fixed(50), 40);
        let (_, tr_s) = run_sdot(&mut net1, &s, &cfg1);

        let mut net2 = SyncNetwork::new(g);
        let cfg2 = SdotConfig::new(Schedule::adaptive(2.0, 1, 50), 40);
        let (_, tr_a) = run_sadot(&mut net2, &s, &cfg2);

        assert!(tr_a.final_p2p() < tr_s.final_p2p());
        // …and with comparable final accuracy.
        assert!(tr_a.final_error() < 1e-5);
    }

    #[test]
    fn sdot_error_decreases() {
        let (s, mut rng) = setting(5, 20, 5, 0.5, 6);
        let g = Graph::erdos_renyi(6, 0.6, &mut rng);
        let mut net = SyncNetwork::new(g);
        let cfg = SdotConfig::new(Schedule::fixed(40), 30);
        let (_, trace) = run_sdot(&mut net, &s, &cfg);
        let first = trace.records.first().unwrap().error;
        let last = trace.final_error();
        assert!(last < first * 1e-3, "first={first} last={last}");
    }

    #[test]
    fn sdot_tracks_exact_consensus_oi() {
        // With a generous consensus budget the distributed iterates track
        // centralized OI (Lemma 1).
        let (s, mut rng) = setting(6, 20, 4, 0.6, 6);
        let g = Graph::erdos_renyi(6, 0.6, &mut rng);
        let mut net = SyncNetwork::new(g);
        let t_o = 25;
        let cfg = SdotConfig::new(Schedule::fixed(120), t_o);
        let (q, _) = run_sdot(&mut net, &s, &cfg);
        let (qc, _) = run_sdot_exact_consensus(&s, t_o);
        for qi in &q {
            assert!(subspace_error(&qc, qi) < 1e-6);
        }
    }

    #[test]
    fn larger_gap_converges_slower() {
        // Δ_r closer to 1 ⇒ slower OI convergence (rate |λ_{r+1}/λ_r|^t).
        let (s_fast, mut rng1) = setting(7, 20, 5, 0.3, 8);
        let g1 = Graph::erdos_renyi(8, 0.5, &mut rng1);
        let mut net1 = SyncNetwork::new(g1);
        let (_, tr_fast) = run_sdot(&mut net1, &s_fast, &SdotConfig::new(Schedule::fixed(50), 25));

        let (s_slow, mut rng2) = setting(7, 20, 5, 0.9, 8);
        let g2 = Graph::erdos_renyi(8, 0.5, &mut rng2);
        let mut net2 = SyncNetwork::new(g2);
        let (_, tr_slow) = run_sdot(&mut net2, &s_slow, &SdotConfig::new(Schedule::fixed(50), 25));

        assert!(
            tr_fast.final_error() < tr_slow.final_error(),
            "fast={} slow={}",
            tr_fast.final_error(),
            tr_slow.final_error()
        );
    }

    #[test]
    fn p2p_equals_schedule_times_degree() {
        let (s, mut rng) = setting(8, 20, 3, 0.5, 6);
        let g = Graph::ring(6);
        let _ = &mut rng;
        let mut net = SyncNetwork::new(g);
        let cfg = SdotConfig::new(Schedule::adaptive(2.0, 1, 50), 12);
        let (_, _) = run_sdot(&mut net, &s, &cfg);
        let expected: usize = (1..=12).map(|t| cfg.schedule.rounds_at(t)).sum::<usize>() * 2;
        for i in 0..6 {
            assert_eq!(net.counters.sent[i], expected as u64);
        }
    }

    #[test]
    fn works_on_repeated_top_eigenvalues() {
        // Fig. 5 regime: λ_1 = … = λ_r; PSA (not PCA) still well-posed.
        let mut rng = Rng::new(9);
        let spec = Spectrum::repeated_top(20, 5, 0.7);
        let ds = SyntheticDataset::full(&spec, 500, 8, &mut rng);
        let s = SampleSetting::from_parts(&ds.parts, 5, &mut rng);
        let g = Graph::erdos_renyi(8, 0.5, &mut rng);
        let mut net = SyncNetwork::new(g);
        let (q, _) = run_sdot(&mut net, &s, &SdotConfig::new(Schedule::fixed(50), 60));
        for qi in &q {
            assert!(subspace_error(&s.truth, qi) < 1e-7);
        }
    }
}
