//! S-DOT and SA-DOT (Algorithm 1) — the paper's core contribution for
//! sample-wise partitioned data.
//!
//! Two-scale iteration: every outer orthogonal iteration computes
//! `Z_i = M_i Q_i^{(t-1)}` locally, runs `T_c(t)` consensus-averaging rounds
//! over the network, rescales by `[W^{T_c} e_1]_i` to estimate the network
//! **sum** `Σ_j M_j Q_j`, and QR-orthonormalizes locally. S-DOT uses a
//! fixed `T_c`; SA-DOT grows it with `t` (Theorem 1 gives both linear
//! convergence to the true eigenspace of `M = Σ_i M_i`).

use super::common::SampleSetting;
use crate::consensus::schedule::Schedule;
use crate::fault::checkpoint::RunCheckpoint;
use crate::linalg::qr::orthonormalize;
use crate::linalg::Mat;
use crate::metrics::subspace::{
    average_error, average_error_masked_ws, average_error_ws, SubspaceWs,
};
use crate::metrics::trace::{IterRecord, RunTrace};
use crate::network::sim::SyncNetwork;
use crate::runtime::pool::DisjointSlice;
use crate::runtime::qr_exec::{orthonormalize_nodes, QrFanScratch};
use crate::runtime::workspace::{node_scratch, MatRowsScratch, NodeScratch};
use crate::runtime::Backend;

/// Configuration for an S-DOT / SA-DOT run.
#[derive(Clone, Copy, Debug)]
pub struct SdotConfig {
    /// Consensus rounds per outer iteration.
    pub schedule: Schedule,
    /// Number of outer (orthogonal) iterations `T_o`.
    pub t_o: usize,
    /// Record a trace point every `record_every` outer iterations
    /// (1 = every iteration).
    pub record_every: usize,
}

impl SdotConfig {
    pub fn new(schedule: Schedule, t_o: usize) -> SdotConfig {
        SdotConfig { schedule, t_o, record_every: 1 }
    }
}

/// A resumable Algorithm-1 run with a persistent workspace.
///
/// All per-iteration buffers — the `Z_i` products, the `XᵀQ`
/// intermediates, the per-node QR scratch, the trace (pre-reserved from
/// `t_o / record_every`), the subspace-metric workspace, and (inside
/// `SyncNetwork`) the consensus double buffer — are allocated at
/// construction and reused by every [`SdotRun::step`], so steady-state
/// outer iterations perform zero heap allocations even at
/// `record_every = 1` (verified by `bench_hotpath`'s counting
/// allocator). Per-node work (step 5's `M_i Q`) fans out across the
/// network's pool **hierarchically** — node chunks first, then rows of
/// each node's product when threads are left over. Step 12's local QR is
/// policy-dispatched through the backend's [`QrPolicy`]: Householder and
/// blocked run node-parallel (sequential per node), while the TSQR
/// policy fans each node's fixed row-block leaves across the pool too
/// (`runtime::qr_exec`), so even N < threads keeps every core busy.
/// Results are bitwise deterministic for any thread count under every
/// policy.
///
/// [`QrPolicy`]: crate::linalg::qr::QrPolicy
pub struct SdotRun<'a> {
    net: &'a mut SyncNetwork,
    setting: &'a SampleSetting,
    cfg: SdotConfig,
    backend: &'a dyn Backend,
    q: Vec<Mat>,
    z: Vec<Mat>,
    /// Per-node phase-A intermediates (`XᵀQ`; `0 × r` for dense covs).
    tmp: Vec<Mat>,
    scratch: Vec<NodeScratch>,
    /// Raw-view table for the hierarchical dispatches (reused, no alloc).
    view_scratch: MatRowsScratch,
    /// TSQR (node × leaf) fan-out workspace for step 12 (reused, no alloc).
    qr_fan: QrFanScratch,
    metric_ws: SubspaceWs,
    trace: RunTrace,
    t: usize,
    total_iters: usize,
}

impl<'a> SdotRun<'a> {
    pub fn new(
        net: &'a mut SyncNetwork,
        setting: &'a SampleSetting,
        cfg: &SdotConfig,
        backend: &'a dyn Backend,
    ) -> SdotRun<'a> {
        let n = net.n();
        assert_eq!(setting.n_nodes(), n, "setting/network size mismatch");
        let d = setting.d();
        let r = setting.q_init.cols;
        let records = cfg.t_o / cfg.record_every.max(1) + 2;
        SdotRun {
            net,
            setting,
            cfg: *cfg,
            backend,
            q: vec![setting.q_init.clone(); n],
            z: (0..n).map(|_| Mat::zeros(d, r)).collect(),
            // Phase-A intermediates are only used by row-split backends;
            // opaque backends route `XᵀQ` through `scratch[i].t0` (which
            // is lazily sized on first use), so don't double-allocate.
            tmp: if backend.supports_row_split() {
                setting.covs.iter().map(|c| Mat::zeros(c.tmp_rows(), r)).collect()
            } else {
                (0..n).map(|_| Mat::zeros(0, r)).collect()
            },
            scratch: node_scratch(n),
            view_scratch: MatRowsScratch::new(),
            qr_fan: QrFanScratch::new(),
            metric_ws: SubspaceWs::new(),
            trace: RunTrace::with_capacity("S-DOT", records),
            t: 0,
            total_iters: 0,
        }
    }

    /// Current per-node estimates.
    pub fn estimates(&self) -> &[Mat] {
        &self.q
    }

    /// Outer iterations completed so far.
    pub fn outer(&self) -> usize {
        self.t
    }

    /// One outer orthogonal iteration (Alg. 1 steps 5–12).
    pub fn step(&mut self) {
        let n = self.q.len();
        self.t += 1;
        let t = self.t;
        // Step 5: local products (the per-node hot path). Row-split
        // backends run it as two hierarchical phases — phase A fills the
        // `XᵀQ` intermediates, phase B the `M_i Q` rows — so when the
        // pool has more threads than nodes the leftover threads split
        // each node's rows (bitwise identical to the single-dispatch
        // path; the kernels are exact row restrictions). Opaque backends
        // keep the node-level dispatch.
        if self.backend.supports_row_split() {
            let q = &self.q;
            let covs = &self.setting.covs;
            let backend = self.backend;
            // Phase A only exists for implicit (sample-held) operators;
            // dense tables skip the dispatch entirely.
            if covs.iter().any(|c| c.tmp_rows() > 0) {
                let tmps = self.view_scratch.fill(self.tmp.as_mut_slice());
                self.net.pool().run_chunks2(n, &|i| covs[i].tmp_rows(), &|i, lo, hi| {
                    // SAFETY: rows [lo, hi) of tmp[i] belong to one task.
                    let ti = unsafe { tmps.rows_mut(i, lo, hi) };
                    backend.cov_apply_tmp_rows(&covs[i], &q[i], lo, hi, ti);
                });
            }
            {
                let zs = self.view_scratch.fill(self.z.as_mut_slice());
                let tmp = &self.tmp;
                let d = self.setting.d();
                self.net.pool().run_chunks2(n, &|_| d, &|i, lo, hi| {
                    // SAFETY: rows [lo, hi) of z[i] belong to one task.
                    let zi = unsafe { zs.rows_mut(i, lo, hi) };
                    backend.cov_apply_out_rows(&covs[i], &q[i], &tmp[i], lo, hi, zi);
                });
            }
        } else {
            let zs = DisjointSlice::new(self.z.as_mut_slice());
            let scr = DisjointSlice::new(self.scratch.as_mut_slice());
            let q = &self.q;
            let covs = &self.setting.covs;
            let backend = self.backend;
            self.net.pool().run_chunks(n, &|lo, hi| {
                for i in lo..hi {
                    // SAFETY: index i belongs to exactly one chunk.
                    let (zi, si) = unsafe { (zs.get_mut(i), scr.get_mut(i)) };
                    backend.cov_apply_into(&covs[i], &q[i], zi, &mut si.t0);
                }
            });
        }
        // Steps 6–11: consensus + rescale to a sum estimate.
        let rounds = self.cfg.schedule.rounds_at(t);
        self.net.consensus_sum(&mut self.z, rounds);
        self.total_iters += rounds;
        // Step 12: local QR through the policy executor — node-parallel
        // for Householder/blocked, (node × leaf) fan-out for TSQR.
        orthonormalize_nodes(
            self.net.pool(),
            self.backend,
            &self.z,
            &mut self.q,
            &mut self.scratch,
            &mut self.qr_fan,
            &mut self.view_scratch,
        );
        if t % self.cfg.record_every == 0 || t == self.cfg.t_o {
            // Under a fault session eq. 11 is averaged over the surviving
            // nodes only — a dead node's frozen estimate is not part of
            // the network any more.
            let error = match self.net.fault_alive() {
                Some(alive) => average_error_masked_ws(
                    &self.setting.truth,
                    &self.q,
                    alive,
                    &mut self.metric_ws,
                ),
                None => average_error_ws(&self.setting.truth, &self.q, &mut self.metric_ws),
            };
            self.trace.push(IterRecord {
                outer: t,
                total_iters: self.total_iters,
                error,
                p2p_avg: self.net.counters.avg(),
            });
        }
    }

    /// Snapshot the full resumable state — per-node estimates, outer and
    /// consensus-iteration counters, trace records, P2P counters, and the
    /// fault session's virtual-clock round stamp. Taken at an
    /// outer-iteration boundary, a run rebuilt from the same inputs and
    /// restored from this snapshot continues **byte-identically** to the
    /// uninterrupted run.
    pub fn checkpoint(&self) -> RunCheckpoint {
        RunCheckpoint {
            algorithm: self.trace.algorithm.clone(),
            t: self.t,
            total_iters: self.total_iters,
            round: self.net.fault_round(),
            q: self.q.clone(),
            records: self.trace.records.clone(),
            sent: self.net.counters.sent.clone(),
            payload: self.net.counters.payload.clone(),
            rng: None,
        }
    }

    /// Restore a snapshot taken by [`SdotRun::checkpoint`] into a freshly
    /// constructed run over the **same** setting, graph, config, and
    /// fault plan. Shapes are validated; on success the next
    /// [`SdotRun::step`] produces exactly the iterates the uninterrupted
    /// run would have produced.
    pub fn restore(&mut self, ck: &RunCheckpoint) -> Result<(), String> {
        if ck.q.len() != self.q.len() {
            return Err(format!(
                "checkpoint has {} node estimates, run has {}",
                ck.q.len(),
                self.q.len()
            ));
        }
        for (i, (cq, q)) in ck.q.iter().zip(&self.q).enumerate() {
            if cq.rows != q.rows || cq.cols != q.cols {
                return Err(format!(
                    "node {i}: checkpoint Q is {}x{}, run expects {}x{}",
                    cq.rows, cq.cols, q.rows, q.cols
                ));
            }
        }
        if ck.sent.len() != self.net.counters.sent.len()
            || ck.payload.len() != self.net.counters.payload.len()
        {
            return Err("checkpoint counter length mismatch".into());
        }
        if ck.t > self.cfg.t_o {
            return Err(format!(
                "checkpoint is at outer iteration {} but the run only has {}",
                ck.t, self.cfg.t_o
            ));
        }
        self.q.clone_from(&ck.q);
        self.t = ck.t;
        self.total_iters = ck.total_iters;
        self.trace.algorithm.clone_from(&ck.algorithm);
        self.trace.records.clone_from(&ck.records);
        self.net.counters.sent.clone_from(&ck.sent);
        self.net.counters.payload.clone_from(&ck.payload);
        self.net.set_fault_round(ck.round);
        Ok(())
    }

    /// Consume the run, returning estimates and trace.
    pub fn finish(self) -> (Vec<Mat>, RunTrace) {
        (self.q, self.trace)
    }
}

/// Run Algorithm 1 on the given network. Returns the per-node estimates and
/// the per-iteration trace. The `backend` computes the `M_i Q` hot path
/// (native Rust or the AOT-compiled XLA artifact).
pub fn run_sdot_with_backend(
    net: &mut SyncNetwork,
    setting: &SampleSetting,
    cfg: &SdotConfig,
    backend: &dyn Backend,
) -> (Vec<Mat>, RunTrace) {
    let mut run = SdotRun::new(net, setting, cfg, backend);
    for _ in 0..cfg.t_o {
        run.step();
    }
    run.finish()
}

/// S-DOT with the native backend (the common path for experiments). The
/// backend snapshots the process-wide `--qr` policy at this call.
pub fn run_sdot(
    net: &mut SyncNetwork,
    setting: &SampleSetting,
    cfg: &SdotConfig,
) -> (Vec<Mat>, RunTrace) {
    run_sdot_with_backend(net, setting, cfg, &crate::runtime::NativeBackend::default())
}

/// S-DOT with periodic checkpointing and optional resume — the driver
/// behind the `--checkpoint-every` / `--resume` knobs. `on_checkpoint`
/// is invoked with a fresh snapshot every `checkpoint_every` completed
/// outer iterations (0 disables snapshots); `resume` restores a prior
/// snapshot before stepping, after which the run continues
/// byte-identically to the uninterrupted one.
pub fn run_sdot_checkpointed(
    net: &mut SyncNetwork,
    setting: &SampleSetting,
    cfg: &SdotConfig,
    resume: Option<&RunCheckpoint>,
    checkpoint_every: usize,
    on_checkpoint: &mut dyn FnMut(&RunCheckpoint),
) -> Result<(Vec<Mat>, RunTrace), String> {
    let backend = crate::runtime::NativeBackend::default();
    let mut run = SdotRun::new(net, setting, cfg, &backend);
    if let Some(ck) = resume {
        run.restore(ck)?;
    }
    while run.outer() < cfg.t_o {
        run.step();
        if checkpoint_every > 0 && run.outer() % checkpoint_every == 0 && run.outer() < cfg.t_o {
            on_checkpoint(&run.checkpoint());
        }
    }
    Ok(run.finish())
}

/// SA-DOT is S-DOT with an adaptive schedule; this wrapper labels the trace.
pub fn run_sadot(
    net: &mut SyncNetwork,
    setting: &SampleSetting,
    cfg: &SdotConfig,
) -> (Vec<Mat>, RunTrace) {
    assert!(
        matches!(cfg.schedule, Schedule::Adaptive { .. }),
        "SA-DOT requires an adaptive schedule"
    );
    let (q, mut trace) = run_sdot(net, setting, cfg);
    trace.algorithm = "SA-DOT".into();
    (q, trace)
}

/// Reference: exact-averaging S-DOT (T_c → ∞ limit). With perfect
/// consensus every node performs centralized OI — used by tests.
pub fn run_sdot_exact_consensus(
    setting: &SampleSetting,
    t_o: usize,
) -> (Mat, RunTrace) {
    let mut q = setting.q_init.clone();
    let mut trace = RunTrace::new("S-DOT(exact)");
    for t in 1..=t_o {
        let v = setting.global_apply(&q);
        q = orthonormalize(&v);
        trace.push(IterRecord {
            outer: t,
            total_iters: t,
            error: average_error(&setting.truth, std::slice::from_ref(&q)),
            p2p_avg: 0.0,
        });
    }
    (q, trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::spectrum::Spectrum;
    use crate::data::synthetic::SyntheticDataset;
    use crate::graph::Graph;
    use crate::metrics::subspace::subspace_error;
    use crate::util::rng::Rng;

    fn setting(seed: u64, d: usize, r: usize, gap: f64, nodes: usize) -> (SampleSetting, Rng) {
        let mut rng = Rng::new(seed);
        let spec = Spectrum::with_gap(d, r, gap);
        let ds = SyntheticDataset::full(&spec, 500, nodes, &mut rng);
        let s = SampleSetting::from_parts(&ds.parts, r, &mut rng);
        (s, rng)
    }

    #[test]
    fn sdot_converges_to_truth() {
        let (s, mut rng) = setting(1, 20, 5, 0.7, 10);
        let g = Graph::erdos_renyi(10, 0.5, &mut rng);
        let mut net = SyncNetwork::new(g);
        let cfg = SdotConfig::new(Schedule::fixed(50), 60);
        let (q, trace) = run_sdot(&mut net, &s, &cfg);
        for qi in &q {
            // Finite T_c leaves a consensus-accuracy error floor (Theorem 1's
            // ε^{T_o} term); 1e-6 is far below any plotted value in Fig. 1.
            assert!(subspace_error(&s.truth, qi) < 1e-6, "err={}", subspace_error(&s.truth, qi));
        }
        assert!(trace.final_error() < 1e-6);
    }

    #[test]
    fn sdot_nodes_reach_consensus() {
        let (s, mut rng) = setting(2, 20, 5, 0.7, 8);
        let g = Graph::erdos_renyi(8, 0.4, &mut rng);
        let mut net = SyncNetwork::new(g);
        let cfg = SdotConfig::new(Schedule::fixed(50), 50);
        let (q, _) = run_sdot(&mut net, &s, &cfg);
        for i in 1..8 {
            // Same subspace at every node.
            assert!(subspace_error(&q[0], &q[i]) < 1e-8);
        }
    }

    #[test]
    fn sadot_converges_with_adaptive_schedule() {
        let (s, mut rng) = setting(3, 20, 5, 0.7, 10);
        let g = Graph::erdos_renyi(10, 0.5, &mut rng);
        let mut net = SyncNetwork::new(g);
        let cfg = SdotConfig::new(Schedule::adaptive(1.0, 1, 50), 80);
        let (q, trace) = run_sadot(&mut net, &s, &cfg);
        assert_eq!(trace.algorithm, "SA-DOT");
        for qi in &q {
            assert!(subspace_error(&s.truth, qi) < 1e-6);
        }
    }

    #[test]
    fn sadot_uses_fewer_messages_than_sdot() {
        let (s, mut rng) = setting(4, 20, 5, 0.7, 10);
        let g = Graph::erdos_renyi(10, 0.5, &mut rng);

        let mut net1 = SyncNetwork::new(g.clone());
        let cfg1 = SdotConfig::new(Schedule::fixed(50), 40);
        let (_, tr_s) = run_sdot(&mut net1, &s, &cfg1);

        let mut net2 = SyncNetwork::new(g);
        let cfg2 = SdotConfig::new(Schedule::adaptive(2.0, 1, 50), 40);
        let (_, tr_a) = run_sadot(&mut net2, &s, &cfg2);

        assert!(tr_a.final_p2p() < tr_s.final_p2p());
        // …and with comparable final accuracy.
        assert!(tr_a.final_error() < 1e-5);
    }

    #[test]
    fn sdot_error_decreases() {
        let (s, mut rng) = setting(5, 20, 5, 0.5, 6);
        let g = Graph::erdos_renyi(6, 0.6, &mut rng);
        let mut net = SyncNetwork::new(g);
        let cfg = SdotConfig::new(Schedule::fixed(40), 30);
        let (_, trace) = run_sdot(&mut net, &s, &cfg);
        let first = trace.records.first().unwrap().error;
        let last = trace.final_error();
        assert!(last < first * 1e-3, "first={first} last={last}");
    }

    #[test]
    fn sdot_tracks_exact_consensus_oi() {
        // With a generous consensus budget the distributed iterates track
        // centralized OI (Lemma 1).
        let (s, mut rng) = setting(6, 20, 4, 0.6, 6);
        let g = Graph::erdos_renyi(6, 0.6, &mut rng);
        let mut net = SyncNetwork::new(g);
        let t_o = 25;
        let cfg = SdotConfig::new(Schedule::fixed(120), t_o);
        let (q, _) = run_sdot(&mut net, &s, &cfg);
        let (qc, _) = run_sdot_exact_consensus(&s, t_o);
        for qi in &q {
            assert!(subspace_error(&qc, qi) < 1e-6);
        }
    }

    #[test]
    fn larger_gap_converges_slower() {
        // Δ_r closer to 1 ⇒ slower OI convergence (rate |λ_{r+1}/λ_r|^t).
        let (s_fast, mut rng1) = setting(7, 20, 5, 0.3, 8);
        let g1 = Graph::erdos_renyi(8, 0.5, &mut rng1);
        let mut net1 = SyncNetwork::new(g1);
        let (_, tr_fast) = run_sdot(&mut net1, &s_fast, &SdotConfig::new(Schedule::fixed(50), 25));

        let (s_slow, mut rng2) = setting(7, 20, 5, 0.9, 8);
        let g2 = Graph::erdos_renyi(8, 0.5, &mut rng2);
        let mut net2 = SyncNetwork::new(g2);
        let (_, tr_slow) = run_sdot(&mut net2, &s_slow, &SdotConfig::new(Schedule::fixed(50), 25));

        assert!(
            tr_fast.final_error() < tr_slow.final_error(),
            "fast={} slow={}",
            tr_fast.final_error(),
            tr_slow.final_error()
        );
    }

    #[test]
    fn p2p_equals_schedule_times_degree() {
        let (s, mut rng) = setting(8, 20, 3, 0.5, 6);
        let g = Graph::ring(6);
        let _ = &mut rng;
        let mut net = SyncNetwork::new(g);
        let cfg = SdotConfig::new(Schedule::adaptive(2.0, 1, 50), 12);
        let (_, _) = run_sdot(&mut net, &s, &cfg);
        let expected: usize = (1..=12).map(|t| cfg.schedule.rounds_at(t)).sum::<usize>() * 2;
        for i in 0..6 {
            assert_eq!(net.counters.sent[i], expected as u64);
        }
    }

    #[test]
    fn checkpoint_resume_is_byte_identical_mid_run() {
        let (s, mut rng) = setting(10, 20, 4, 0.6, 8);
        let g = Graph::erdos_renyi(8, 0.5, &mut rng);
        let cfg = SdotConfig::new(Schedule::fixed(30), 24);
        let backend = crate::runtime::NativeBackend::default();

        // Uninterrupted reference.
        let mut net_a = SyncNetwork::new(g.clone());
        let (q_ref, tr_ref) = run_sdot_with_backend(&mut net_a, &s, &cfg, &backend);

        // Kill at t = 11, snapshot, rebuild from scratch, restore, finish.
        let mut net_b = SyncNetwork::new(g.clone());
        let ck = {
            let mut run = SdotRun::new(&mut net_b, &s, &cfg, &backend);
            for _ in 0..11 {
                run.step();
            }
            run.checkpoint()
        };
        // Round-trip the snapshot through its JSON encoding, exactly like
        // a real kill/resume through a file on disk.
        let ck = RunCheckpoint::parse(&ck.to_json().to_string()).unwrap();
        let mut net_c = SyncNetwork::new(g);
        let mut run = SdotRun::new(&mut net_c, &s, &cfg, &backend);
        run.restore(&ck).unwrap();
        while run.outer() < cfg.t_o {
            run.step();
        }
        let (q_res, tr_res) = run.finish();

        for (a, b) in q_ref.iter().zip(&q_res) {
            for (x, y) in a.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        assert_eq!(tr_ref.records.len(), tr_res.records.len());
        for (a, b) in tr_ref.records.iter().zip(&tr_res.records) {
            assert_eq!(a.outer, b.outer);
            assert_eq!(a.total_iters, b.total_iters);
            assert_eq!(a.error.to_bits(), b.error.to_bits());
            assert_eq!(a.p2p_avg.to_bits(), b.p2p_avg.to_bits());
        }
        assert_eq!(net_a.counters.sent, net_c.counters.sent);
        assert_eq!(net_a.counters.payload, net_c.counters.payload);
    }

    #[test]
    fn checkpoint_resume_under_faults_matches_uninterrupted() {
        use crate::fault::FaultPlan;
        let (s, mut rng) = setting(11, 20, 4, 0.6, 8);
        let g = Graph::from_spec("complete", 8, 0.0, &mut rng);
        let cfg = SdotConfig::new(Schedule::fixed(25), 20);
        let plan = FaultPlan::none().with_loss(0.05, 42).with_node_down(3, 60);
        let backend = crate::runtime::NativeBackend::default();

        let mut net_a = SyncNetwork::new(g.clone());
        net_a.install_fault_plan(plan.clone()).unwrap();
        let (q_ref, _) = run_sdot_with_backend(&mut net_a, &s, &cfg, &backend);

        let mut net_b = SyncNetwork::new(g.clone());
        net_b.install_fault_plan(plan.clone()).unwrap();
        let ck = {
            let mut run = SdotRun::new(&mut net_b, &s, &cfg, &backend);
            for _ in 0..7 {
                run.step();
            }
            run.checkpoint()
        };
        // The virtual-clock stamp rides in the snapshot.
        assert_eq!(ck.round, 7 * 25);

        let mut net_c = SyncNetwork::new(g);
        net_c.install_fault_plan(plan).unwrap();
        let mut run = SdotRun::new(&mut net_c, &s, &cfg, &backend);
        run.restore(&ck).unwrap();
        while run.outer() < cfg.t_o {
            run.step();
        }
        let (q_res, _) = run.finish();

        for (a, b) in q_ref.iter().zip(&q_res) {
            for (x, y) in a.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        assert_eq!(net_a.counters.sent, net_c.counters.sent);
        assert_eq!(net_a.counters.payload, net_c.counters.payload);
    }

    #[test]
    fn run_sdot_checkpointed_snapshots_and_resumes() {
        let (s, mut rng) = setting(12, 20, 4, 0.6, 6);
        let g = Graph::erdos_renyi(6, 0.6, &mut rng);
        let cfg = SdotConfig::new(Schedule::fixed(30), 18);

        let mut net_a = SyncNetwork::new(g.clone());
        let (q_ref, _) =
            run_sdot_checkpointed(&mut net_a, &s, &cfg, None, 0, &mut |_| {}).unwrap();

        // Snapshot every 5 outer iterations, keep the latest, then resume
        // a fresh run from it.
        let mut snaps: Vec<RunCheckpoint> = Vec::new();
        let mut net_b = SyncNetwork::new(g.clone());
        let _ = run_sdot_checkpointed(&mut net_b, &s, &cfg, None, 5, &mut |ck| {
            snaps.push(ck.clone());
        })
        .unwrap();
        assert_eq!(snaps.iter().map(|c| c.t).collect::<Vec<_>>(), vec![5, 10, 15]);

        let mut net_c = SyncNetwork::new(g);
        let (q_res, _) =
            run_sdot_checkpointed(&mut net_c, &s, &cfg, snaps.last(), 0, &mut |_| {}).unwrap();
        for (a, b) in q_ref.iter().zip(&q_res) {
            for (x, y) in a.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn restore_rejects_mismatched_shapes() {
        let (s, mut rng) = setting(13, 20, 4, 0.6, 6);
        let g = Graph::erdos_renyi(6, 0.6, &mut rng);
        let cfg = SdotConfig::new(Schedule::fixed(10), 8);
        let backend = crate::runtime::NativeBackend::default();
        let mut net = SyncNetwork::new(g);
        let mut run = SdotRun::new(&mut net, &s, &cfg, &backend);
        run.step();
        let mut ck = run.checkpoint();
        ck.q.pop();
        assert!(run.restore(&ck).is_err());
        let mut ck2 = run.checkpoint();
        ck2.q[0] = Mat::zeros(3, 3);
        assert!(run.restore(&ck2).is_err());
        let mut ck3 = run.checkpoint();
        ck3.t = 99;
        assert!(run.restore(&ck3).is_err());
    }

    #[test]
    fn sdot_under_fixed_fault_plan_is_bitwise_equal_across_threads_and_converges() {
        use crate::fault::FaultPlan;
        // The ISSUE's acceptance scenario: a fixed FaultPlan (node death
        // at a virtual time, 5% message loss) must reproduce bit-exactly
        // at --threads ∈ {1, 4}, with the run converging (eq. 11 error
        // decreasing) on the surviving connected subgraph instead of
        // panicking.
        let (s, mut rng) = setting(14, 20, 4, 0.6, 10);
        let g = Graph::from_spec("complete", 10, 0.0, &mut rng);
        let plan = FaultPlan::none()
            .with_loss(0.05, 7)
            .with_node_churn(4, 40, 120)
            .with_node_down(7, 200);
        let cfg = SdotConfig::new(Schedule::fixed(20), 30);

        let mut runs = Vec::new();
        for threads in [1usize, 4] {
            let mut net = SyncNetwork::with_threads(g.clone(), threads);
            net.install_fault_plan(plan.clone()).unwrap();
            let (q, trace) = run_sdot(&mut net, &s, &cfg);
            runs.push((q, trace, net.counters.sent.clone(), net.counters.payload.clone()));
        }
        let (q1, tr1, sent1, payload1) = &runs[0];
        let (q4, tr4, sent4, payload4) = &runs[1];
        for (a, b) in q1.iter().zip(q4.iter()) {
            for (x, y) in a.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        for (a, b) in tr1.records.iter().zip(&tr4.records) {
            assert_eq!(a.error.to_bits(), b.error.to_bits());
        }
        assert_eq!(sent1, sent4);
        assert_eq!(payload1, payload4);
        // Graceful degradation: the surviving-subgraph error decreases.
        let first = tr1.records.first().unwrap().error;
        let last = tr1.final_error();
        assert!(last < first * 1e-1, "first={first} last={last}");
        assert!(last.is_finite());
    }

    #[test]
    fn works_on_repeated_top_eigenvalues() {
        // Fig. 5 regime: λ_1 = … = λ_r; PSA (not PCA) still well-posed.
        let mut rng = Rng::new(9);
        let spec = Spectrum::repeated_top(20, 5, 0.7);
        let ds = SyntheticDataset::full(&spec, 500, 8, &mut rng);
        let s = SampleSetting::from_parts(&ds.parts, 5, &mut rng);
        let g = Graph::erdos_renyi(8, 0.5, &mut rng);
        let mut net = SyncNetwork::new(g);
        let (q, _) = run_sdot(&mut net, &s, &SdotConfig::new(Schedule::fixed(50), 60));
        for qi in &q {
            assert!(subspace_error(&s.truth, qi) < 1e-7);
        }
    }
}
