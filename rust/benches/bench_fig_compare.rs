//! Regenerates paper Figures 4 and 5 (baseline comparison, distinct and
//! repeated eigenvalues).
use dpsa::util::bench::{bench_ctx, run_and_print};

fn main() {
    let ctx = bench_ctx(0.2);
    run_and_print("fig4", &ctx);
    run_and_print("fig5", &ctx);
}
