//! Parallel-scaling benchmark: node-only vs **hierarchical** (node × row)
//! dispatch, plus trial-level fan-out — the two parallelism levels behind
//! the single `--threads` knob.
//!
//! The within-node rungs run a d ∈ {784, 2914} (LFW-shaped) S-DOT cell on
//! an N = 2 complete graph — the regime where node-only chunking strands
//! all but two threads. Three modes are timed at identical arithmetic:
//!
//! * `t1`       — serial baseline;
//! * `t4_flat`  — 4 threads, node-level chunking only (`split_rows = false`,
//!                the pre-hierarchical behaviour: at most 2 threads busy);
//! * `t4_hier`  — 4 threads, hierarchical row-split dispatch.
//!
//! Every mode's estimates are asserted **bitwise identical** before any
//! timing is reported — speed must come from scheduling, never from
//! arithmetic drift. The trial-level section times a Table-I-style cell
//! (4 Monte-Carlo trials) with the trial pool off vs on and asserts the
//! averaged outputs are bit-equal.
//!
//! Results go to `BENCH_parallel.json` (override with `BENCH_JSON_OUT`);
//! CI uploads it next to the hotpath/straggler ledgers.
//!
//! Run: `cargo bench --bench bench_parallel_scaling`

use dpsa::algorithms::sdot::{run_sdot, SdotConfig};
use dpsa::algorithms::SampleSetting;
use dpsa::consensus::schedule::Schedule;
use dpsa::data::spectrum::Spectrum;
use dpsa::data::synthetic::SyntheticDataset;
use dpsa::experiments::{synth_tables, ExpCtx};
use dpsa::graph::Graph;
use dpsa::linalg::Mat;
use dpsa::network::sim::SyncNetwork;
use dpsa::util::bench::{time_it, BenchReport};
use dpsa::util::rng::Rng;

fn assert_bitwise(a: &[Mat], b: &[Mat], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: node count");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(x.data, y.data, "{what}: node {i} differs bitwise");
    }
}

fn main() {
    println!("== parallel scaling: node-only vs hierarchical (N=2) ==\n");
    let mut report = BenchReport::new();
    let threads = 4usize;

    // ---- within-node scaling at d ∈ {784, 2914}, N = 2 ----------------
    for &(d, r, n_i, t_c, t_o) in &[(784usize, 5usize, 192usize, 4usize, 6usize), (2914, 7, 128, 3, 4)] {
        let nodes = 2;
        let mut rng = Rng::new(42);
        let spec = Spectrum::with_gap(d, r, 0.7);
        // Spiked sampler keeps setup O(d·m) at d = 2914; n_i < d keeps
        // the covariances in the implicit form (the two-phase split
        // target, exactly how the LFW tables hold their data).
        let ds = SyntheticDataset::spiked(&spec, 8, n_i, nodes, &mut rng);
        let setting = SampleSetting::from_parts(&ds.parts, r, &mut rng);
        let g = Graph::complete(nodes);
        let mut cfg = SdotConfig::new(Schedule::fixed(t_c), t_o);
        cfg.record_every = t_o;

        let modes: [(&str, usize, bool); 3] =
            [("t1", 1, true), ("t4_flat", threads, false), ("t4_hier", threads, true)];
        let mut q_ref: Option<Vec<Mat>> = None;
        let mut secs = [0.0f64; 3];
        for (mi, &(mode, t, split)) in modes.iter().enumerate() {
            // Correctness first: all modes must agree bitwise.
            let mut net = SyncNetwork::with_threads_split(g.clone(), t, split);
            let (q, _) = run_sdot(&mut net, &setting, &cfg);
            match &q_ref {
                None => q_ref = Some(q),
                Some(want) => assert_bitwise(want, &q, mode),
            }
            let timing = time_it(1, 5, || {
                let mut net = SyncNetwork::with_threads_split(g.clone(), t, split);
                std::hint::black_box(run_sdot(&mut net, &setting, &cfg));
            });
            secs[mi] = timing.median.as_secs_f64();
            println!("S-DOT cell d={d:<4} N=2 r={r} T_c={t_c} T_o={t_o}  {mode:>8}: {timing}");
            report.push(&format!("sdot_d{d}_n2_{mode}_ns"), timing.median.as_nanos() as f64);
        }
        let node_only = secs[0] / secs[1].max(1e-12);
        let hier = secs[0] / secs[2].max(1e-12);
        println!(
            "  speedup vs serial — node-only: {node_only:.2}x, hierarchical: {hier:.2}x \
             (hier/node-only: {:.2}x)\n",
            secs[1] / secs[2].max(1e-12)
        );
        report.push(&format!("sdot_d{d}_n2_node_only_speedup"), node_only);
        report.push(&format!("sdot_d{d}_n2_hier_speedup"), hier);
        if d == 2914 && hier <= node_only {
            eprintln!(
                "  WARNING: hierarchical did not beat node-only at d={d} \
                 (expected on ≥4 hardware threads; CI runners vary)"
            );
        }
    }

    // ---- pooled dense Gram build (syrk row kernel) ---------------------
    // Demonstrates and prices the pooled Gram-build pattern: the
    // experiment runners themselves still build dense covariances with
    // the serial triangle-and-mirror `syrk` (their d ≤ 128 shapes don't
    // warrant a pool), so `syrk_rows_into` is exercised here and by the
    // shape-sweep property tests — it is the kernel a future pooled
    // `CovOp` construction path would use. The mirror-free row kernel
    // spends 2× the serial triangle's flops, so the ceiling on 4 threads
    // is ~2× — measured here and asserted bitwise against serial.
    {
        use dpsa::runtime::pool::NodePool;
        use dpsa::runtime::MatRowsScratch;
        let (d, n_s) = (784usize, 512usize);
        let mut rng = Rng::new(7);
        let x = Mat::gauss(d, n_s, &mut rng);
        let scale = 1.0 / n_s as f64;
        let want = x.syrk(scale);
        let pool = NodePool::new(threads);
        let mut out = vec![Mat::zeros(d, d)];
        let pooled_syrk = |out: &mut Vec<Mat>| {
            let mut scratch = MatRowsScratch::new();
            let dst = scratch.fill(out.as_mut_slice());
            pool.run_chunks2(1, &|_| d, &|i, lo, hi| {
                // SAFETY: each task owns rows [lo, hi) of the Gram.
                let rows = unsafe { dst.rows_mut(i, lo, hi) };
                x.syrk_rows_into(scale, lo, hi, rows);
            });
        };
        pooled_syrk(&mut out);
        assert_eq!(out[0].data, want.data, "pooled syrk must match serial bitwise");
        let t_serial = time_it(1, 5, || {
            std::hint::black_box(x.syrk(scale));
        });
        let t_pooled = time_it(1, 5, || {
            pooled_syrk(&mut out);
            std::hint::black_box(&out);
        });
        let speedup = t_serial.median.as_secs_f64() / t_pooled.median.as_secs_f64().max(1e-12);
        println!("\ndense Gram d={d} n={n_s}  serial syrk: {t_serial}");
        println!("dense Gram d={d} n={n_s}  pooled rows: {t_pooled}  ({speedup:.2}x)\n");
        report.push("gram_d784_serial_ns", t_serial.median.as_nanos() as f64);
        report.push("gram_d784_pooled_t4_ns", t_pooled.median.as_nanos() as f64);
        report.push("gram_d784_pooled_speedup", speedup);
    }

    // ---- trial-level scaling: a Table-I cell, 4 MC trials -------------
    let base = ExpCtx {
        seed: 42,
        scale: 0.1,
        trials: 4,
        threads,
        trial_parallel: false,
        ..Default::default()
    };
    let t_o = base.scaled(synth_tables::T_O);
    let cell = |ctx: &ExpCtx| {
        synth_tables::run_cell(ctx, 20, 0.25, 5, 0.7, Schedule::fixed(50), t_o, "erdos")
    };
    let serial_out = cell(&base);
    let par_ctx = ExpCtx { trial_parallel: true, ..base.clone() };
    let par_out = cell(&par_ctx);
    assert_eq!(
        (serial_out.0.to_bits(), serial_out.1.to_bits()),
        (par_out.0.to_bits(), par_out.1.to_bits()),
        "trial-parallel cell must be bit-identical to the serial loop"
    );
    let t_serial = time_it(1, 3, || {
        std::hint::black_box(cell(&base));
    });
    let t_par = time_it(1, 3, || {
        std::hint::black_box(cell(&par_ctx));
    });
    let speedup = t_serial.median.as_secs_f64() / t_par.median.as_secs_f64().max(1e-12);
    println!("Table-I cell, 4 trials  serial:         {t_serial}");
    println!("Table-I cell, 4 trials  trial-parallel: {t_par}  ({speedup:.2}x)");
    report.push("table1_cell_4trials_serial_ns", t_serial.median.as_nanos() as f64);
    report.push("table1_cell_4trials_parallel_ns", t_par.median.as_nanos() as f64);
    report.push("table1_cell_trial_parallel_speedup", speedup);

    report.save("BENCH_parallel.json");
}
