//! N-scaling benchmark: the sparse consensus path at N up to 10⁴.
//!
//! The paper's tables stop at N = 20; the scalability rework makes a
//! consensus round cost O(active edges) instead of the dense O(N²)
//! matrix-vector sweep. This bench pins that contract:
//!
//! * per-round wall time across N ∈ {10², 10³, 10⁴} × {ring, grid, er},
//!   with the per-edge normalization recorded so the ledger shows the
//!   round cost tracking edges, not N²;
//! * a counting-allocator **assertion** that the steady-state sparse
//!   round allocates nothing;
//! * a small-N bitwise pin: sparse weights and mixing reproduce the
//!   dense reference exactly;
//! * the node-multiplexed SPMD runtime at N = 10³ across worker counts
//!   (10³ logical nodes on a handful of OS threads — the dedicated
//!   thread-per-node runtime stops far earlier).
//!
//! Results go to `BENCH_scale.json` (override with `BENCH_JSON_OUT`),
//! the perf ledger's N-scaling artifact.
//!
//! Run: `cargo bench --bench bench_scale`

use dpsa::consensus::weights::{local_degree_weights, sparse_local_degree_weights, SparseWeights};
use dpsa::graph::Graph;
use dpsa::linalg::Mat;
use dpsa::network::mpi::{run_spmd_mux, MpiConfig};
use dpsa::network::sim::SyncNetwork;
use dpsa::runtime::spmd::MuxProgram;
use dpsa::util::bench::{alloc_snapshot, time_it, BenchReport, CountingAlloc};
use dpsa::util::rng::Rng;
use std::sync::Arc;

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// ER draws p = 2·ln(N)/N — twice the connectivity threshold, ≈ N·ln N
/// edges; ring/grid ignore p.
fn build(topo: &str, n: usize, rng: &mut Rng) -> Graph {
    let p = (2.0 * (n as f64).ln() / n as f64).min(1.0);
    Graph::from_spec(topo, n, p, rng)
}

/// One logical node of plain sparse consensus on the multiplexed SPMD
/// runtime: publish the current value, absorb the Metropolis mix of the
/// neighbors' published values.
struct MixProg {
    i: usize,
    sw: Arc<SparseWeights>,
    z: Mat,
    tmp: Mat,
}

impl MuxProgram for MixProg {
    fn dims(&self) -> (usize, usize) {
        (self.z.rows, self.z.cols)
    }

    fn publish(&self, _round: u64, out: &mut Mat) {
        out.copy_from(&self.z);
    }

    fn absorb(&mut self, _round: u64, _neighbors: &[usize], board: &[Mat]) {
        self.tmp.copy_from(&self.z);
        self.tmp.scale_inplace(self.sw.diag[self.i]);
        let (cols, vals) = self.sw.row(self.i);
        for (&j, &w) in cols.iter().zip(vals.iter()) {
            self.tmp.axpy(w, &board[j]);
        }
        std::mem::swap(&mut self.z, &mut self.tmp);
    }
}

fn main() {
    println!("== N-scaling: sparse consensus up to 10^4 nodes ==\n");
    let mut rng = Rng::new(42);
    let mut report = BenchReport::new();

    // --- per-round cost across N × topology ------------------------------
    for &n in &[100usize, 1_000, 10_000] {
        for topo in ["ring", "grid", "er"] {
            let g = build(topo, n, &mut rng);
            let edges = g.adj.iter().map(|a| a.len()).sum::<usize>() / 2;
            let mut net = SyncNetwork::with_threads(g, 1);
            let mut z: Vec<Mat> = (0..n).map(|_| Mat::gauss(4, 2, &mut rng)).collect();
            net.consensus(&mut z, 1); // warm-up: shapes the workspace
            let (reps, iters) = if n >= 10_000 { (1, 5) } else { (2, 9) };
            let t = time_it(reps, iters, || {
                net.consensus(&mut z, 1);
            });
            let per_edge = t.median.as_nanos() as f64 / edges.max(1) as f64;
            println!(
                "consensus round  {topo:<4} N={n:<6} edges={edges:<7}: {t}  \
                 ({per_edge:.1} ns/edge)"
            );
            report.push_timing(&format!("consensus_round_{topo}_n{n}_ns"), &t);
            report.push(&format!("consensus_round_{topo}_n{n}_ns_per_edge"), per_edge);
        }
    }
    println!("  (O(edges) contract: ns/edge stays flat while N grows 100x)\n");

    // --- zero-allocation assertion on the steady-state sparse round ------
    {
        let g = build("er", 1_000, &mut rng);
        let mut net = SyncNetwork::with_threads(g, 1);
        let mut z: Vec<Mat> = (0..1_000).map(|_| Mat::gauss(4, 2, &mut rng)).collect();
        net.consensus(&mut z, 2); // warm-up
        let (a0, b0) = alloc_snapshot();
        net.consensus(&mut z, 8);
        let (a1, b1) = alloc_snapshot();
        println!(
            "steady-state sparse rounds (x8, N=1000): {} allocations, {} bytes",
            a1 - a0,
            b1 - b0
        );
        assert_eq!(a1 - a0, 0, "sparse consensus round allocated in steady state");
        report.push("sparse_round_steady_state_allocs", (a1 - a0) as f64);
    }
    println!();

    // --- small-N bitwise pin: sparse ≡ dense ------------------------------
    {
        let mut rng2 = Rng::new(7);
        let g = Graph::erdos_renyi(16, 0.4, &mut rng2);
        let dense = local_degree_weights(&g);
        let sparse = sparse_local_degree_weights(&g);
        let sd = sparse.to_dense();
        for (a, b) in dense.w.data.iter().zip(sd.w.data.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "sparse weights diverge from dense");
        }
        let z0: Vec<Mat> = (0..16).map(|_| Mat::gauss(4, 2, &mut rng2)).collect();
        let mut z = z0.clone();
        let mut net = SyncNetwork::with_threads(g.clone(), 1);
        net.consensus(&mut z, 1);
        for i in 0..16 {
            let mut want = z0[i].scale(dense.w.get(i, i));
            for &j in &g.adj[i] {
                want.axpy(dense.w.get(i, j), &z0[j]);
            }
            for (a, b) in z[i].data.iter().zip(want.data.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "sparse round diverges at node {i}");
            }
        }
        println!("sparse == dense bitwise at N=16 (weights + one round): ok");
        report.push("sparse_dense_bitwise_n16_ok", 1.0);
    }
    println!();

    // --- node-multiplexed SPMD: 10^3 logical nodes, few workers ----------
    {
        let n = 1_000usize;
        let g = build("er", n, &mut rng);
        let sw = Arc::new(sparse_local_degree_weights(&g));
        let rounds = 20u64;
        for &workers in &[1usize, 4, 8] {
            let t = time_it(1, 3, || {
                let mut r2 = Rng::new(99);
                let programs: Vec<MixProg> = (0..n)
                    .map(|i| MixProg {
                        i,
                        sw: sw.clone(),
                        z: Mat::gauss(2, 2, &mut r2),
                        tmp: Mat::zeros(2, 2),
                    })
                    .collect();
                let run = run_spmd_mux(&g, &MpiConfig::virtual_clock(), workers, rounds, programs);
                std::hint::black_box(&run.programs);
            });
            println!("mux consensus  N={n} rounds={rounds} workers={workers}: {t}");
            report.push_timing(&format!("mux_consensus_n{n}_w{workers}_ns"), &t);
        }
        println!("  (bitwise worker-count invariance is pinned in tests/test_scale_parity.rs)");
    }

    report.save("BENCH_scale.json");
}
