//! Regenerates paper Figures 7–12 (real-data communication cost and
//! baseline comparisons on the dataset surrogates).
use dpsa::util::bench::{bench_ctx, run_and_print};

fn main() {
    let ctx = bench_ctx(0.1);
    for id in ["fig7", "fig8", "fig9", "fig10", "fig11", "fig12"] {
        run_and_print(id, &ctx);
    }
}
