//! L3 hot-path microbenchmarks (§Perf): the per-iteration costs that
//! bound end-to-end throughput — `M_i Q` (native vs XLA), QR, one
//! consensus round, and a full Table-I cell.
//!
//! Run: `cargo bench --bench bench_hotpath`

use dpsa::algorithms::sdot::{run_sdot, SdotConfig};
use dpsa::algorithms::SampleSetting;
use dpsa::consensus::schedule::Schedule;
use dpsa::data::spectrum::Spectrum;
use dpsa::data::synthetic::SyntheticDataset;
use dpsa::graph::Graph;
use dpsa::linalg::{CovOp, Mat};
use dpsa::network::sim::SyncNetwork;
use dpsa::runtime::{Backend, NativeBackend, XlaBackend};
use dpsa::util::bench::time_it;
use dpsa::util::rng::Rng;

fn main() {
    println!("== L3 hot-path microbenchmarks ==\n");
    let mut rng = Rng::new(42);

    // --- cov_apply: dense d=20 and d=784, native vs XLA -----------------
    for &(d, r, n_samp) in &[(20usize, 5usize, 500usize), (784, 5, 500)] {
        let x = Mat::gauss(d, n_samp, &mut rng);
        let cov_dense = CovOp::dense_from_samples(&x);
        let q = Mat::random_orthonormal(d, r, &mut rng);
        let native = NativeBackend;
        let t = time_it(3, 21, || {
            std::hint::black_box(native.cov_apply(&cov_dense, &q));
        });
        println!("cov_apply native  d={d:<4} r={r}: {t}");

        let dir = XlaBackend::default_dir();
        if XlaBackend::available(&dir) {
            let be = XlaBackend::load(&dir).expect("load artifacts");
            let t = time_it(3, 21, || {
                std::hint::black_box(be.cov_apply(&cov_dense, &q));
            });
            println!("cov_apply xla     d={d:<4} r={r}: {t}");
            let t = time_it(3, 21, || {
                std::hint::black_box(be.oi_step(&cov_dense, &q));
            });
            println!("oi_step   xla     d={d:<4} r={r}: {t} (fused matmul+MGS)");
        }

        // Implicit (sample) representation.
        let cov_lr = CovOp::Samples { x: x.clone(), scale: 1.0 / n_samp as f64 };
        let t = time_it(3, 21, || {
            std::hint::black_box(native.cov_apply(&cov_lr, &q));
        });
        println!("cov_apply samples d={d:<4} r={r}: {t}\n");
    }

    // --- QR --------------------------------------------------------------
    for &(d, r) in &[(20usize, 5usize), (784, 5), (2914, 7)] {
        let v = Mat::gauss(d, r, &mut rng);
        let t = time_it(3, 21, || {
            std::hint::black_box(dpsa::linalg::qr::orthonormalize(&v));
        });
        println!("householder_qr    d={d:<4} r={r}: {t}");
    }
    println!();

    // --- one consensus round, N=20 ---------------------------------------
    for &(d, r) in &[(20usize, 5usize), (784, 5), (2914, 7)] {
        let g = Graph::erdos_renyi(20, 0.25, &mut rng);
        let mut net = SyncNetwork::new(g);
        let mut z: Vec<Mat> = (0..20).map(|_| Mat::gauss(d, r, &mut rng)).collect();
        let t = time_it(3, 21, || {
            net.consensus(&mut z, 1);
        });
        println!("consensus round   d={d:<4} r={r} N=20: {t}");
    }
    println!();

    // --- full Table-I cell (N=20, T_o=200, T_c=50, d=20) -----------------
    let spec = Spectrum::with_gap(20, 5, 0.7);
    let ds = SyntheticDataset::full(&spec, 500, 20, &mut rng);
    let setting = SampleSetting::from_parts(&ds.parts, 5, &mut rng);
    let g = Graph::erdos_renyi(20, 0.25, &mut rng);
    let t = time_it(1, 5, || {
        let mut net = SyncNetwork::new(g.clone());
        let mut cfg = SdotConfig::new(Schedule::fixed(50), 200);
        cfg.record_every = 200;
        std::hint::black_box(run_sdot(&mut net, &setting, &cfg));
    });
    println!("full Table-I cell (N=20, T_o=200, T_c=50): {t}");
    println!("  (§Perf target: < 2 s)");
}
