//! L3 hot-path microbenchmarks (§Perf): the per-iteration costs that
//! bound end-to-end throughput — `M_i Q` (native vs XLA), QR, one
//! consensus round, and a full Table-I cell — plus the zero-allocation
//! proof: a counting global allocator measures heap allocations across
//! steady-state S-DOT outer iterations (must be 0 after warm-up).
//!
//! Results are also written as JSON (per-kernel ns + Table-I-cell wall
//! time + allocation counts) to `BENCH_hotpath.json` (override with
//! `BENCH_JSON_OUT`) so CI can track the perf trajectory as an artifact.
//!
//! Run: `cargo bench --bench bench_hotpath`

use dpsa::algorithms::sdot::{run_sdot, SdotConfig, SdotRun};
use dpsa::algorithms::SampleSetting;
use dpsa::consensus::schedule::Schedule;
use dpsa::data::spectrum::Spectrum;
use dpsa::data::synthetic::SyntheticDataset;
use dpsa::graph::Graph;
use dpsa::linalg::{CovOp, Mat};
use dpsa::network::sim::SyncNetwork;
use dpsa::runtime::{Backend, NativeBackend, XlaBackend};
use dpsa::util::bench::{alloc_snapshot, time_it, BenchReport, CountingAlloc};
use dpsa::util::rng::Rng;

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn main() {
    println!("== L3 hot-path microbenchmarks ==\n");
    let mut rng = Rng::new(42);
    let mut report = BenchReport::new();

    // --- cov_apply: dense d=20 and d=784, native vs XLA -----------------
    for &(d, r, n_samp) in &[(20usize, 5usize, 500usize), (784, 5, 500)] {
        let x = Mat::gauss(d, n_samp, &mut rng);
        let cov_dense = CovOp::dense_from_samples(&x);
        let q = Mat::random_orthonormal(d, r, &mut rng);
        let native = NativeBackend::default();
        let t = time_it(3, 21, || {
            std::hint::black_box(native.cov_apply(&cov_dense, &q));
        });
        println!("cov_apply native  d={d:<4} r={r}: {t}");
        report.push_timing(&format!("cov_apply_native_d{d}_ns"), &t);

        // Allocation-free variant through the workspace path.
        let mut out = Mat::zeros(d, r);
        let mut tmp = Mat::zeros(0, 0);
        let t = time_it(3, 21, || {
            native.cov_apply_into(&cov_dense, &q, &mut out, &mut tmp);
            std::hint::black_box(&out);
        });
        println!("cov_apply into    d={d:<4} r={r}: {t}");
        report.push_timing(&format!("cov_apply_into_d{d}_ns"), &t);

        let dir = XlaBackend::default_dir();
        if XlaBackend::available(&dir) {
            let be = XlaBackend::load(&dir).expect("load artifacts");
            let t = time_it(3, 21, || {
                std::hint::black_box(be.cov_apply(&cov_dense, &q));
            });
            println!("cov_apply xla     d={d:<4} r={r}: {t}");
            let t = time_it(3, 21, || {
                std::hint::black_box(be.oi_step(&cov_dense, &q));
            });
            println!("oi_step   xla     d={d:<4} r={r}: {t} (fused matmul+MGS)");
        }

        // Implicit (sample) representation.
        let cov_lr = CovOp::Samples { x: x.clone(), scale: 1.0 / n_samp as f64 };
        let t = time_it(3, 21, || {
            std::hint::black_box(native.cov_apply(&cov_lr, &q));
        });
        println!("cov_apply samples d={d:<4} r={r}: {t}\n");
        report.push_timing(&format!("cov_apply_samples_d{d}_ns"), &t);
    }

    // --- QR --------------------------------------------------------------
    for &(d, r) in &[(20usize, 5usize), (784, 5), (2914, 7)] {
        let v = Mat::gauss(d, r, &mut rng);
        let t = time_it(3, 21, || {
            std::hint::black_box(dpsa::linalg::qr::orthonormalize(&v));
        });
        println!("householder_qr       d={d:<4} r={r}: {t}");
        report.push_timing(&format!("qr_d{d}_ns"), &t);

        let mut q = Mat::zeros(d, r);
        let mut ws = dpsa::linalg::QrScratch::new();
        let t = time_it(3, 21, || {
            dpsa::linalg::qr::orthonormalize_into(&v, &mut q, &mut ws);
            std::hint::black_box(&q);
        });
        println!("householder_qr into  d={d:<4} r={r}: {t}");
        report.push_timing(&format!("qr_into_d{d}_ns"), &t);
    }
    println!();

    // --- one consensus round, N=20, threads ∈ {1, 4} ---------------------
    for &(d, r) in &[(20usize, 5usize), (784, 5), (2914, 7)] {
        for &threads in &[1usize, 4] {
            let g = Graph::erdos_renyi(20, 0.25, &mut rng);
            let mut net = SyncNetwork::with_threads(g, threads);
            let mut z: Vec<Mat> = (0..20).map(|_| Mat::gauss(d, r, &mut rng)).collect();
            let t = time_it(3, 21, || {
                net.consensus(&mut z, 1);
            });
            println!("consensus round   d={d:<4} r={r} N=20 threads={threads}: {t}");
            report.push_timing(&format!("consensus_d{d}_t{threads}_ns"), &t);
        }
    }
    println!();

    // --- zero-allocation proof: steady-state S-DOT outer iterations -----
    let spec = Spectrum::with_gap(20, 5, 0.7);
    let ds = SyntheticDataset::full(&spec, 500, 20, &mut rng);
    let setting = SampleSetting::from_parts(&ds.parts, 5, &mut rng);
    let g = Graph::erdos_renyi(20, 0.25, &mut rng);
    {
        let mut net = SyncNetwork::with_threads(g.clone(), 1);
        // `record_every = 1` is the adversarial setting: every step runs
        // the subspace metric and pushes a trace record. The metric
        // workspace + pre-reserved trace keep even this allocation-free.
        let cfg = SdotConfig::new(Schedule::fixed(50), 1_000);
        let backend = NativeBackend::default();
        let mut run = SdotRun::new(&mut net, &setting, &cfg, &backend);
        for _ in 0..3 {
            run.step(); // warm-up: shapes the persistent workspace
        }
        let (a0, b0) = alloc_snapshot();
        let steps = 5;
        for _ in 0..steps {
            run.step();
        }
        let (a1, b1) = alloc_snapshot();
        let (q, _) = run.finish();
        std::hint::black_box(&q);
        println!(
            "steady-state S-DOT outer iterations (x{steps}, record_every=1): \
             {} allocations, {} bytes",
            a1 - a0,
            b1 - b0
        );
        println!("  (§Perf target: 0 — every buffer reused after warm-up)");
        report.push("sdot_steady_state_allocs_per_5_iters", (a1 - a0) as f64);
        report.push("sdot_steady_state_alloc_bytes_per_5_iters", (b1 - b0) as f64);
    }
    println!();

    // --- full Table-I cell (N=20, T_o=200, T_c=50, d=20) -----------------
    let mut serial_secs = 0.0f64;
    for &threads in &[1usize, 4] {
        let t = time_it(1, 5, || {
            let mut net = SyncNetwork::with_threads(g.clone(), threads);
            let mut cfg = SdotConfig::new(Schedule::fixed(50), 200);
            cfg.record_every = 200;
            std::hint::black_box(run_sdot(&mut net, &setting, &cfg));
        });
        let secs = t.median.as_secs_f64();
        if threads == 1 {
            serial_secs = secs;
            println!("full Table-I cell (N=20, T_o=200, T_c=50) threads=1: {t}");
        } else {
            println!(
                "full Table-I cell (N=20, T_o=200, T_c=50) threads={threads}: {t}  \
                 ({:.2}x vs threads=1)",
                serial_secs / secs.max(1e-12)
            );
        }
        report.push(&format!("table1_cell_t{threads}_ns"), t.median.as_nanos() as f64);
    }
    println!("  (§Perf target: < 2 s; acceptance: threads=4 ≥ 2x the serial seed)");

    report.save("BENCH_hotpath.json");
}
