//! Regenerates paper Figures 1–3 (error curves: schedules, connectivity,
//! ring & star). Curves land in results/fig{1,2,3}/trace_*.csv.
use dpsa::util::bench::{bench_ctx, run_and_print};

fn main() {
    let ctx = bench_ctx(0.25);
    for id in ["fig1", "fig2", "fig3"] {
        run_and_print(id, &ctx);
    }
}
