//! SIMD micro-kernel ladder: `--simd scalar|auto|fma` priced on the
//! dot4/GEMM hot paths.
//!
//! Times the three `SimdPolicy` tiers on the kernels the knob dispatches:
//! the skinny `M_i Q` product at the paper's real-data dimensions
//! d ∈ {784, 2914} (dense operator and the implicit `X (XᵀQ)` form),
//! the blocked GEMM, and the d×d Gram/`syrk` — and proves the
//! zero-allocation steady state at every policy with a counting global
//! allocator.
//!
//! `scalar` vs `auto` differ in speed only (bitwise-identical results —
//! the determinism contract `test_simd_kernels` locks); `fma` changes
//! bits by design, so its timings are a separate ledger column, never a
//! drop-in comparison.
//!
//! Results land in `BENCH_simd.json` (override with `BENCH_JSON_OUT`) —
//! uploaded by CI next to the other perf ledgers. Derived
//! `simd_*_speedup_*` keys express auto/fma wins over the scalar
//! baseline at the same shape.
//!
//! Run: `cargo bench --bench bench_simd`

use dpsa::linalg::simd::SimdPolicy;
use dpsa::linalg::{CovOp, Mat};
use dpsa::util::bench::{alloc_snapshot, time_it, BenchReport, CountingAlloc};
use dpsa::util::rng::Rng;

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn main() {
    println!("== SIMD micro-kernel benchmarks (dot4 / GEMM hot path) ==\n");
    for policy in SimdPolicy::ALL {
        println!("policy {:<6} resolves to {:?}", policy.name(), policy.resolve());
    }
    println!();

    let mut rng = Rng::new(42);
    let mut report = BenchReport::new();

    // --- skinny M_i Q, dense operator (the ROADMAP's "biggest single
    // win" shape: d×d · d×r with r = 5) --------------------------------
    for &d in &[784usize, 2914] {
        let a = Mat::gauss(d, d, &mut rng);
        let q = Mat::gauss(d, 5, &mut rng);
        let mut out = Mat::zeros(d, 5);
        let mut scalar_ns = 0.0;
        for policy in SimdPolicy::ALL {
            let t = time_it(2, 9, || {
                a.matmul_into_with(&q, &mut out, policy);
                std::hint::black_box(&out);
            });
            let ns = t.median.as_nanos() as f64;
            if policy == SimdPolicy::Scalar {
                scalar_ns = ns;
                println!("skinny MQ {:<6} d={d:<4}: {t}", policy.name());
            } else {
                println!(
                    "skinny MQ {:<6} d={d:<4}: {t}  ({:.2}x vs scalar)",
                    policy.name(),
                    scalar_ns / ns.max(1.0)
                );
                report.push(
                    &format!("simd_{}_speedup_skinny_d{d}", policy.name()),
                    scalar_ns / ns.max(1.0),
                );
            }
            report.push(&format!("simd_{}_skinny_d{d}_ns", policy.name()), ns);
        }
        println!();
    }

    // --- implicit M_i Q = (1/s) X (XᵀQ) at LFW scale ------------------
    {
        let (d, s, r) = (2914usize, 200usize, 5usize);
        let x = Mat::gauss(d, s, &mut rng);
        let cov = CovOp::Samples { x, scale: 1.0 / s as f64 };
        let q = Mat::gauss(d, r, &mut rng);
        let mut out = Mat::zeros(0, 0);
        let mut tmp = Mat::zeros(0, 0);
        let mut scalar_ns = 0.0;
        for policy in SimdPolicy::ALL {
            let t = time_it(2, 9, || {
                cov.apply_into_with(&q, &mut out, &mut tmp, policy);
                std::hint::black_box(&out);
            });
            let ns = t.median.as_nanos() as f64;
            if policy == SimdPolicy::Scalar {
                scalar_ns = ns;
                println!("implicit MQ {:<6} d={d}: {t}", policy.name());
            } else {
                println!(
                    "implicit MQ {:<6} d={d}: {t}  ({:.2}x vs scalar)",
                    policy.name(),
                    scalar_ns / ns.max(1.0)
                );
                report.push(
                    &format!("simd_{}_speedup_implicit_d{d}", policy.name()),
                    scalar_ns / ns.max(1.0),
                );
            }
            report.push(&format!("simd_{}_implicit_d{d}_ns", policy.name()), ns);
        }
        println!();
    }

    // --- blocked GEMM and the d×d Gram (syrk) -------------------------
    {
        let a = Mat::gauss(256, 256, &mut rng);
        let b = Mat::gauss(256, 256, &mut rng);
        let mut out = Mat::zeros(256, 256);
        let mut scalar_ns = 0.0;
        for policy in SimdPolicy::ALL {
            let t = time_it(2, 9, || {
                a.matmul_into_with(&b, &mut out, policy);
                std::hint::black_box(&out);
            });
            let ns = t.median.as_nanos() as f64;
            if policy == SimdPolicy::Scalar {
                scalar_ns = ns;
                println!("gemm 256³  {:<6}: {t}", policy.name());
            } else {
                println!(
                    "gemm 256³  {:<6}: {t}  ({:.2}x vs scalar)",
                    policy.name(),
                    scalar_ns / ns.max(1.0)
                );
                report.push(
                    &format!("simd_{}_speedup_gemm256", policy.name()),
                    scalar_ns / ns.max(1.0),
                );
            }
            report.push(&format!("simd_{}_gemm256_ns", policy.name()), ns);
        }
        println!();
    }
    {
        let (d, k) = (784usize, 300usize);
        let x = Mat::gauss(d, k, &mut rng);
        let mut out = Mat::zeros(d, d);
        let mut scalar_ns = 0.0;
        for policy in SimdPolicy::ALL {
            let t = time_it(1, 5, || {
                x.syrk_into_with(1.0 / k as f64, &mut out, policy);
                std::hint::black_box(&out);
            });
            let ns = t.median.as_nanos() as f64;
            if policy == SimdPolicy::Scalar {
                scalar_ns = ns;
                println!("syrk d={d} {:<6}: {t}", policy.name());
            } else {
                println!(
                    "syrk d={d} {:<6}: {t}  ({:.2}x vs scalar)",
                    policy.name(),
                    scalar_ns / ns.max(1.0)
                );
                report.push(
                    &format!("simd_{}_speedup_syrk_d{d}", policy.name()),
                    scalar_ns / ns.max(1.0),
                );
            }
            report.push(&format!("simd_{}_syrk_d{d}_ns", policy.name()), ns);
        }
        println!();
    }

    // --- zero-allocation proof: steady state at every policy ----------
    let mut total_allocs = 0u64;
    {
        let (d, s, r) = (2914usize, 200usize, 5usize);
        let x = Mat::gauss(d, s, &mut rng);
        let cov = CovOp::Samples { x, scale: 1.0 / s as f64 };
        let q = Mat::gauss(d, r, &mut rng);
        let a = Mat::gauss(256, 256, &mut rng);
        let b = Mat::gauss(256, 256, &mut rng);
        let g = Mat::gauss(100, 64, &mut rng);
        let mut out = Mat::zeros(0, 0);
        let mut tmp = Mat::zeros(0, 0);
        let mut gout = Mat::zeros(256, 256);
        let mut sout = Mat::zeros(100, 100);
        for policy in SimdPolicy::ALL {
            // Warm every scratch arena at this policy's shapes…
            for _ in 0..2 {
                cov.apply_into_with(&q, &mut out, &mut tmp, policy);
                a.matmul_into_with(&b, &mut gout, policy);
                g.syrk_into_with(1.0 / 64.0, &mut sout, policy);
            }
            // …then the steady state must not allocate at all.
            let (a0, _) = alloc_snapshot();
            for _ in 0..5 {
                cov.apply_into_with(&q, &mut out, &mut tmp, policy);
                a.matmul_into_with(&b, &mut gout, policy);
                g.syrk_into_with(1.0 / 64.0, &mut sout, policy);
            }
            let (a1, _) = alloc_snapshot();
            let allocs = a1 - a0;
            total_allocs += allocs;
            println!(
                "steady-state {} (M_i Q + gemm + syrk): {allocs} allocations over 5 iters",
                policy.name()
            );
            assert_eq!(allocs, 0, "{policy:?} allocated in steady state");
        }
    }
    println!("  (§Perf target: 0 — every buffer reused after warm-up)");
    report.push("simd_steady_state_allocs", total_allocs as f64);

    report.save("BENCH_simd.json");
}
