//! Regenerates paper Tables I and II (synthetic S-DOT/SA-DOT P2P).
//! `BENCH_SCALE=1.0 BENCH_TRIALS=20 cargo bench --bench bench_tables_synth`
//! reproduces paper-fidelity grids.
use dpsa::util::bench::{bench_ctx, run_and_print};

fn main() {
    let ctx = bench_ctx(0.25);
    run_and_print("table1", &ctx);
    run_and_print("table2", &ctx);
}
