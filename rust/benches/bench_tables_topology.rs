//! Regenerates paper Tables III and IV (ring and star topologies).
use dpsa::util::bench::{bench_ctx, run_and_print};

fn main() {
    let ctx = bench_ctx(0.25);
    run_and_print("table3", &ctx);
    run_and_print("table4", &ctx);
}
