//! Regenerates paper Figure 6 (F-DOT vs OI/SeqPM/d-PM, feature-wise).
use dpsa::util::bench::{bench_ctx, run_and_print};

fn main() {
    let ctx = bench_ctx(0.25);
    run_and_print("fig6", &ctx);
}
