//! QR-policy microbenchmarks — the Alg. 1 step-12 ladder.
//!
//! Times the three `QrPolicy` kernels (scalar Householder, blocked
//! compact-WY, TSQR) at the paper's real-data shapes d ∈ {784, 2914}
//! with r ∈ {5, 40}, plus the pooled (node × leaf) TSQR fan-out in the
//! N < threads regime ROADMAP targeted, and proves the zero-allocation
//! steady state of every policy with a counting global allocator.
//!
//! Results land in `BENCH_qr.json` (override with `BENCH_JSON_OUT`) —
//! uploaded by CI next to the other perf ledgers. Derived
//! `qr_*_speedup_*` keys express blocked / pooled-TSQR wins over the
//! scalar baseline at the same shape.
//!
//! Run: `cargo bench --bench bench_qr`

use dpsa::linalg::qr::{orthonormalize_policy_into, tsqr_leaves, QrPolicy, QrScratch};
use dpsa::linalg::Mat;
use dpsa::runtime::qr_exec::orthonormalize_nodes;
use dpsa::runtime::{node_scratch, MatRowsScratch, NativeBackend, NodePool, QrFanScratch};
use dpsa::util::bench::{alloc_snapshot, time_it, BenchReport, CountingAlloc};
use dpsa::util::rng::Rng;

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn main() {
    println!("== QR policy microbenchmarks (Alg. 1 step 12) ==\n");
    let mut rng = Rng::new(42);
    let mut report = BenchReport::new();

    for &(d, r) in &[(784usize, 5usize), (784, 40), (2914, 5), (2914, 40)] {
        let v = Mat::gauss(d, r, &mut rng);
        let mut householder_ns = 0.0;
        for policy in QrPolicy::ALL {
            let mut q = Mat::zeros(0, 0);
            let mut ws = QrScratch::new();
            let t = time_it(3, 15, || {
                orthonormalize_policy_into(&v, &mut q, &mut ws, policy);
                std::hint::black_box(&q);
            });
            let ns = t.median.as_nanos() as f64;
            if policy == QrPolicy::Householder {
                householder_ns = ns;
                println!("qr {:<12} d={d:<4} r={r:<2}: {t}", policy.name());
            } else {
                println!(
                    "qr {:<12} d={d:<4} r={r:<2}: {t}  ({:.2}x vs householder)",
                    policy.name(),
                    householder_ns / ns.max(1.0)
                );
                report.push(
                    &format!("qr_{}_speedup_d{d}_r{r}", policy.name()),
                    householder_ns / ns.max(1.0),
                );
            }
            report.push(&format!("qr_{}_d{d}_r{r}_ns", policy.name()), ns);
        }

        // Pooled TSQR fan-out: N = 2 nodes × leaf tasks on 4 threads —
        // the d-large / N-small regime where per-node QR was the last
        // serial stage. Reported per QR (the dispatch covers 2).
        let leaves = tsqr_leaves(d, r);
        let pool = NodePool::new(4);
        let z: Vec<Mat> = (0..2).map(|_| Mat::gauss(d, r, &mut rng)).collect();
        let mut q: Vec<Mat> = (0..2).map(|_| Mat::zeros(0, 0)).collect();
        let mut scratch = node_scratch(2);
        let mut fan = QrFanScratch::new();
        let mut views = MatRowsScratch::new();
        let backend = NativeBackend::with_policy(QrPolicy::Tsqr);
        let t = time_it(3, 15, || {
            orthonormalize_nodes(&pool, &backend, &z, &mut q, &mut scratch, &mut fan, &mut views);
            std::hint::black_box(&q);
        });
        let per_qr_ns = t.median.as_nanos() as f64 / 2.0;
        println!(
            "qr tsqr-pool4    d={d:<4} r={r:<2}: {t}  (2 QRs, {leaves} leaves each; \
             {:.2}x vs householder per QR)\n",
            householder_ns / per_qr_ns.max(1.0)
        );
        report.push(&format!("qr_tsqr_pool4_d{d}_r{r}_ns"), per_qr_ns);
        report.push(
            &format!("qr_tsqr_pool4_speedup_d{d}_r{r}"),
            householder_ns / per_qr_ns.max(1.0),
        );
    }

    // --- zero-allocation proof: steady-state QR at every policy ---------
    let mut total_allocs = 0u64;
    for &(d, r) in &[(2914usize, 5usize), (2914, 40)] {
        let v = Mat::gauss(d, r, &mut rng);
        for policy in QrPolicy::ALL {
            let mut q = Mat::zeros(0, 0);
            let mut ws = QrScratch::new();
            orthonormalize_policy_into(&v, &mut q, &mut ws, policy);
            orthonormalize_policy_into(&v, &mut q, &mut ws, policy);
            let (a0, _) = alloc_snapshot();
            for _ in 0..5 {
                orthonormalize_policy_into(&v, &mut q, &mut ws, policy);
            }
            let (a1, _) = alloc_snapshot();
            let allocs = a1 - a0;
            total_allocs += allocs;
            println!(
                "steady-state {} d={d} r={r}: {allocs} allocations over 5 QRs",
                policy.name()
            );
            assert_eq!(allocs, 0, "{policy:?} allocated in steady state");
        }
    }
    println!("  (§Perf target: 0 — every buffer reused after warm-up)");
    report.push("qr_steady_state_allocs", total_allocs as f64);

    report.save("BENCH_qr.json");
}
