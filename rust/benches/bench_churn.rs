//! Fault-injection benchmark: kill/resume byte-identity and the
//! no-fault-path allocation contract of the fault layer.
//!
//! * **Kill/resume byte-identity**: one churn cell (complete graph, 5%
//!   message loss + scripted node churn) runs uninterrupted, then again
//!   "killed" at the midpoint — the mid-run [`RunCheckpoint`] is pushed
//!   through its JSON serialization (exactly what `--checkpoint-every` /
//!   `--resume` persist) and the resumed run must reproduce the
//!   uninterrupted final state digest bit-for-bit.
//! * **No-fault path stays allocation-free**: installing a trivial
//!   `FaultPlan` must leave the steady-state S-DOT loop on the exact
//!   pre-fault hot path — the counting allocator asserts 0 allocations
//!   after warm-up, same contract `bench_hotpath` pins for the plain
//!   simulator.
//! * The fault path itself is measured (wall-clock overhead vs the
//!   fault-free cell, steady-state allocations) and reported, not
//!   asserted — faulty rounds may allocate on membership epochs.
//!
//! Results go to `BENCH_churn.json` (override with `BENCH_JSON_OUT`).
//!
//! Run: `cargo bench --bench bench_churn`

use dpsa::algorithms::sdot::{run_sdot, run_sdot_checkpointed, SdotConfig, SdotRun};
use dpsa::algorithms::SampleSetting;
use dpsa::consensus::schedule::Schedule;
use dpsa::data::spectrum::Spectrum;
use dpsa::data::synthetic::SyntheticDataset;
use dpsa::experiments::churn::scripted_plan;
use dpsa::fault::checkpoint::RunCheckpoint;
use dpsa::fault::FaultPlan;
use dpsa::graph::Graph;
use dpsa::metrics::trace::RunTrace;
use dpsa::network::sim::SyncNetwork;
use dpsa::runtime::NativeBackend;
use dpsa::util::bench::{alloc_snapshot, bench_ctx, time_it, BenchReport, CountingAlloc};
use dpsa::util::rng::Rng;

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Fingerprint the complete final state of a finished run: estimates,
/// trace records, P2P counters, and the virtual-round stamp.
fn final_digest(q: Vec<dpsa::linalg::Mat>, trace: &RunTrace, net: &SyncNetwork, t: usize) -> u64 {
    RunCheckpoint {
        algorithm: trace.algorithm.clone(),
        t,
        total_iters: trace.total_iters(),
        round: net.fault_round(),
        q,
        records: trace.records.clone(),
        sent: net.counters.sent.clone(),
        payload: net.counters.payload.clone(),
        rng: None,
    }
    .digest()
}

fn main() {
    println!("== churn / fault-injection benchmark ==\n");
    let ctx = bench_ctx(0.1);
    let mut report = BenchReport::new();

    let n = 20;
    let t_o = ctx.scaled(60).max(8);
    let schedule = Schedule::fixed(20);
    let plan = scripted_plan(0.05, schedule.total_rounds(t_o) as u64);
    let mut rng = Rng::new(ctx.seed);
    let spec = Spectrum::with_gap(20, 5, 0.7);
    let ds = SyntheticDataset::full(&spec, 500, n, &mut rng);
    let setting = SampleSetting::from_parts(&ds.parts, 5, &mut rng);
    let g = Graph::complete(n);
    let cfg = SdotConfig::new(schedule, t_o);

    // --- kill/resume byte-identity --------------------------------------
    let mut net = SyncNetwork::with_threads(g.clone(), ctx.threads);
    net.install_fault_plan(plan.clone()).unwrap();
    let start = std::time::Instant::now();
    let (q_full, tr_full) =
        run_sdot_checkpointed(&mut net, &setting, &cfg, None, 0, &mut |_| {}).unwrap();
    let full_wall = start.elapsed();
    let full_digest = final_digest(q_full, &tr_full, &net, t_o);
    println!(
        "uninterrupted churn cell N={n} T_o={t_o}: {:.3}s, final error {:.2e}",
        full_wall.as_secs_f64(),
        tr_full.final_error()
    );
    report.push("churn_cell_uninterrupted_ns", full_wall.as_nanos() as f64);

    // Kill at the midpoint: snapshot, roundtrip through the JSON the
    // `--checkpoint-every` machinery persists, then resume fresh.
    let t_mid = t_o / 2;
    let ck = {
        let mut net = SyncNetwork::with_threads(g.clone(), ctx.threads);
        net.install_fault_plan(plan.clone()).unwrap();
        let backend = NativeBackend::default();
        let mut run = SdotRun::new(&mut net, &setting, &cfg, &backend);
        for _ in 0..t_mid {
            run.step();
        }
        run.checkpoint()
    };
    let ck = RunCheckpoint::parse(&ck.to_json().to_string()).unwrap();
    assert_eq!(ck.t, t_mid);
    let mut net = SyncNetwork::with_threads(g.clone(), ctx.threads);
    net.install_fault_plan(plan.clone()).unwrap();
    let start = std::time::Instant::now();
    let (q_res, tr_res) =
        run_sdot_checkpointed(&mut net, &setting, &cfg, Some(&ck), 0, &mut |_| {}).unwrap();
    let resumed_wall = start.elapsed();
    let resumed_digest = final_digest(q_res, &tr_res, &net, t_o);
    assert_eq!(
        full_digest, resumed_digest,
        "a run killed at t={t_mid} and resumed must be byte-identical"
    );
    println!(
        "killed at t={t_mid} + resumed: {:.3}s — state digest matches ({full_digest:016x})",
        resumed_wall.as_secs_f64()
    );
    report.push("churn_resume_digest_match", 1.0);
    report.push("churn_cell_resumed_half_ns", resumed_wall.as_nanos() as f64);

    // --- no-fault path: installing a trivial plan keeps the steady-state
    // S-DOT loop allocation-free (the pre-fault hot-path contract) ------
    {
        let mut net = SyncNetwork::with_threads(g.clone(), 1);
        net.install_fault_plan(FaultPlan::none()).unwrap(); // trivial: uninstalls
        let backend = NativeBackend::default();
        let cfg = SdotConfig::new(Schedule::fixed(20), 1_000);
        let mut run = SdotRun::new(&mut net, &setting, &cfg, &backend);
        for _ in 0..3 {
            run.step();
        }
        let (a0, _) = alloc_snapshot();
        for _ in 0..5 {
            run.step();
        }
        let (a1, _) = alloc_snapshot();
        let allocs = a1 - a0;
        println!("no-fault steady state (trivial plan installed): {allocs} allocs / 5 iters");
        assert_eq!(allocs, 0, "the fault layer must not touch the fault-free hot path");
        report.push("nofault_steady_state_allocs_per_5_iters", allocs as f64);
    }

    // --- fault-path cost (reported, not asserted) ------------------------
    {
        let mut net = SyncNetwork::with_threads(g.clone(), 1);
        net.install_fault_plan(plan.clone()).unwrap();
        let backend = NativeBackend::default();
        let cfg = SdotConfig::new(Schedule::fixed(20), 1_000);
        let mut run = SdotRun::new(&mut net, &setting, &cfg, &backend);
        for _ in 0..3 {
            run.step();
        }
        let (a0, _) = alloc_snapshot();
        for _ in 0..5 {
            run.step();
        }
        let (a1, _) = alloc_snapshot();
        println!("faulty steady state: {} allocs / 5 iters", a1 - a0);
        report.push("faulty_steady_state_allocs_per_5_iters", (a1 - a0) as f64);
    }
    let mut cell_cfg = SdotConfig::new(schedule, t_o);
    cell_cfg.record_every = t_o;
    let t_plain = time_it(1, 3, || {
        let mut net = SyncNetwork::with_threads(g.clone(), ctx.threads);
        std::hint::black_box(run_sdot(&mut net, &setting, &cell_cfg));
    });
    let t_faulty = time_it(1, 3, || {
        let mut net = SyncNetwork::with_threads(g.clone(), ctx.threads);
        net.install_fault_plan(plan.clone()).unwrap();
        std::hint::black_box(run_sdot(&mut net, &setting, &cell_cfg));
    });
    let overhead = t_faulty.median.as_secs_f64() / t_plain.median.as_secs_f64().max(1e-12);
    println!("fault-free cell {t_plain}\nfaulty cell     {t_faulty}");
    println!("fault-path overhead: {overhead:.2}x");
    report.push("churn_cell_plain_ns", t_plain.median.as_nanos() as f64);
    report.push("churn_cell_faulty_ns", t_faulty.median.as_nanos() as f64);
    report.push("churn_fault_overhead_ratio", overhead);

    report.save("BENCH_churn.json");
}
