//! Straggler-runtime benchmark (paper Table V) on the pooled MPI-like
//! runtime, in **both clock modes**, plus the zero-allocation proof for
//! the recycled-buffer exchange path.
//!
//! * A counting global allocator measures heap allocations inside the
//!   steady-state `NodeCtx::exchange` loop (after `prime_buffers` + a
//!   warm-up) — must be 0 per round on every node.
//! * One small Table-V cell (N=10, p=0.5, fixed T_c) runs under the
//!   virtual clock (asserted bit-equal to the `expected_sync_vtime`
//!   reference cascade) and under the real clock (wall-clock ≥ the
//!   virtual floor).
//!
//! Results are written as JSON to `BENCH_straggler.json` (override with
//! `BENCH_JSON_OUT`) so CI can track them as an artifact alongside
//! `BENCH_hotpath.json`. Scale the cell with `BENCH_SCALE`.
//!
//! Run: `cargo bench --bench bench_straggler`

use dpsa::algorithms::SampleSetting;
use dpsa::consensus::schedule::Schedule;
use dpsa::data::spectrum::Spectrum;
use dpsa::data::synthetic::SyntheticDataset;
use dpsa::experiments::straggler::run_sdot_mpi;
use dpsa::graph::Graph;
use dpsa::linalg::Mat;
use dpsa::network::mpi::{
    expected_sync_vtime, run_spmd, ClockMode, MpiConfig, StragglerSpec,
};
use dpsa::util::bench::{alloc_snapshot, bench_ctx, BenchReport, CountingAlloc};
use dpsa::util::rng::Rng;
use std::time::Duration;

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Steady-state allocation count inside `NodeCtx::exchange`: after
/// priming the buffer pool and a warm-up, `measure` rounds must allocate
/// nothing on any node. The cooldown keeps every node exchanging until
/// all measurement windows have closed (blocking sync keeps nodes within
/// `capacity` rounds of each other, and cooldown > capacity), so no
/// node's teardown allocations can leak into another's window.
fn exchange_steady_state_allocs(g: &Graph, warmup: u64, measure: u64) -> u64 {
    let cfg = MpiConfig::virtual_clock()
        .with_straggler(StragglerSpec { delay: Duration::from_millis(1), seed: 5 });
    let cooldown = 2 * cfg.capacity as u64 + 4;
    let run = run_spmd(g, &cfg, move |ctx| {
        let m = Mat::gauss(20, 5, &mut Rng::new(17 + ctx.rank as u64));
        ctx.prime_buffers(&m);
        for _ in 0..warmup {
            ctx.exchange(&m);
        }
        let (a0, _) = alloc_snapshot();
        for _ in 0..measure {
            ctx.exchange(&m);
        }
        let (a1, _) = alloc_snapshot();
        for _ in 0..cooldown {
            ctx.exchange(&m);
        }
        a1 - a0
    });
    run.results.into_iter().max().unwrap_or(0)
}

fn main() {
    println!("== straggler runtime benchmark (pooled MPI-like runtime) ==\n");
    let ctx = bench_ctx(0.1);
    let mut report = BenchReport::new();

    // --- zero-allocation steady state on the exchange hot path ---------
    // First run warms the SPMD worker pool and the result-channel path so
    // one-time setup allocations land outside the measured windows.
    let g = Graph::ring(8);
    exchange_steady_state_allocs(&g, 4, 4);
    let allocs = exchange_steady_state_allocs(&g, 12, 50);
    println!("exchange steady state: {allocs} allocs over 50 rounds (worst node)");
    assert_eq!(allocs, 0, "NodeCtx::exchange must be allocation-free after warm-up");
    report.push("exchange_steady_state_allocs_per_50_rounds", allocs as f64);

    // --- one small Table-V cell, both clock modes -----------------------
    let n = 10;
    let p = 0.5;
    let t_o = ctx.scaled(40);
    let delay = Duration::from_millis(2);
    let sched = Schedule::fixed(20);
    let mut rng = Rng::new(ctx.seed);
    let spec = Spectrum::with_gap(20, 5, 0.7);
    let ds = SyntheticDataset::full(&spec, 500, n, &mut rng);
    let setting = SampleSetting::from_parts(&ds.parts, 5, &mut rng);
    let graph = Graph::erdos_renyi(n, p, &mut rng);
    let spec_s = StragglerSpec { delay, seed: ctx.seed };

    let vcfg = MpiConfig::virtual_clock().with_straggler(spec_s);
    let virt = run_sdot_mpi(&setting, &graph, sched, t_o, &vcfg);
    let floor = expected_sync_vtime(&graph, &spec_s, sched.total_rounds(t_o) as u64);
    assert_eq!(
        virt.secs,
        floor.as_secs_f64(),
        "virtual cascade must match the reference recurrence bit-exactly"
    );
    println!(
        "table5 cell N={n} p={p} T_o={t_o} virtual: {:.3}s cascade, P2P avg {:.0}",
        virt.secs, virt.p2p_avg
    );
    report.push("table5_cell_virtual_cascade_ns", floor.as_nanos() as f64);
    report.push("table5_cell_p2p_avg", virt.p2p_avg);

    let rcfg = MpiConfig { clock: ClockMode::Real, ..vcfg };
    let start = std::time::Instant::now();
    let real = run_sdot_mpi(&setting, &graph, sched, t_o, &rcfg);
    let wall = start.elapsed();
    assert!(
        real.secs >= floor.as_secs_f64(),
        "real sleeps never undershoot the virtual floor: {} < {}",
        real.secs,
        floor.as_secs_f64()
    );
    assert_eq!(real.p2p_avg, virt.p2p_avg, "clock mode must not change P2P accounting");
    println!(
        "table5 cell N={n} p={p} T_o={t_o} real:    {:.3}s wall (floor {:.3}s)",
        real.secs,
        floor.as_secs_f64()
    );
    report.push("table5_cell_real_wall_ns", wall.as_nanos() as f64);

    report.save("BENCH_straggler.json");
}
