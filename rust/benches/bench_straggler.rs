//! Regenerates paper Table V (straggler wall-clock on the threaded
//! MPI-like runtime). Default scale keeps the straggled runs ~10 s;
//! BENCH_SCALE=1.0 reproduces the paper's ~100 s cells.
use dpsa::util::bench::{bench_ctx, run_and_print};

fn main() {
    let ctx = bench_ctx(0.1);
    run_and_print("table5", &ctx);
}
