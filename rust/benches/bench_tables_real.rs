//! Regenerates paper Tables VI–IX (MNIST/CIFAR-10/LFW/ImageNet P2P).
use dpsa::util::bench::{bench_ctx, run_and_print};

fn main() {
    let ctx = bench_ctx(0.25);
    for id in ["table6", "table7", "table8", "table9"] {
        run_and_print(id, &ctx);
    }
}
