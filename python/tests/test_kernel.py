"""L1 Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (and, where meaningful, dtypes) and asserts
allclose against ref.py — the core correctness signal before AOT export.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.combine import combine
from compile.kernels.gram import gram
from compile.kernels.matmul import matmul, vmem_footprint_bytes, _default_block

jax.config.update("jax_enable_x64", False)


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# --- matmul -----------------------------------------------------------------

dims = st.sampled_from([4, 8, 12, 16, 20, 24, 48, 64])
ranks = st.integers(min_value=1, max_value=10)


@settings(max_examples=25, deadline=None)
@given(d_out=dims, d_in=dims, r=ranks, seed=st.integers(0, 2**30))
def test_matmul_matches_ref(d_out, d_in, r, seed):
    m = rand(seed, (d_out, d_in))
    q = rand(seed + 1, (d_in, r))
    got = matmul(m, q)
    want = ref.matmul_ref(m, q)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_matmul_explicit_blocks(seed):
    m = rand(seed, (20, 20))
    q = rand(seed + 1, (20, 5))
    for bm, bk in [(4, 4), (10, 10), (20, 20), (5, 2)]:
        got = matmul(m, q, bm=bm, bk=bk)
        np.testing.assert_allclose(got, ref.matmul_ref(m, q), rtol=1e-4, atol=1e-5)


def test_matmul_f32_and_bf16():
    m32 = rand(0, (16, 16))
    q32 = rand(1, (16, 4))
    out32 = matmul(m32, q32)
    assert out32.dtype == jnp.float32
    m16 = m32.astype(jnp.bfloat16)
    q16 = q32.astype(jnp.bfloat16)
    out16 = matmul(m16, q16)
    assert out16.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        out16.astype(jnp.float32), out32, rtol=5e-2, atol=5e-2
    )


def test_matmul_identity():
    q = rand(2, (12, 3))
    np.testing.assert_allclose(matmul(jnp.eye(12), q), q, rtol=1e-6)


def test_default_block_divides():
    for dim in [20, 64, 500, 784, 1024, 2914]:
        b = _default_block(dim)
        assert dim % b == 0 and 1 <= b <= 1024
        # TPU-targeted cap still available for tiling studies.
        b128 = _default_block(dim, cap=128)
        assert dim % b128 == 0 and 1 <= b128 <= 128


def test_vmem_footprint_fits_vmem():
    # DESIGN §Perf: tiles + accumulator must fit 16 MiB VMEM with
    # double-buffering for every artifact shape.
    for d, r in [(20, 5), (64, 8), (784, 5)]:
        bm = bk = _default_block(d)
        assert vmem_footprint_bytes(d, r, bm, bk) < 16 * 2**20


# --- gram -------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    d=st.sampled_from([4, 8, 16, 20, 32]),
    n=st.sampled_from([8, 32, 100, 256]),
    seed=st.integers(0, 2**30),
)
def test_gram_matches_ref(d, n, seed):
    x = rand(seed, (d, n))
    np.testing.assert_allclose(gram(x), ref.gram_ref(x), rtol=1e-3, atol=1e-6)


def test_gram_symmetric_psd():
    x = rand(3, (16, 64))
    m = np.array(gram(x))
    np.testing.assert_allclose(m, m.T, atol=1e-6)
    eig = np.linalg.eigvalsh(m)
    assert eig.min() > -1e-6


# --- combine ----------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    k=st.integers(1, 8),
    d=st.sampled_from([4, 10, 20]),
    r=st.integers(1, 6),
    seed=st.integers(0, 2**30),
)
def test_combine_matches_ref(k, d, r, seed):
    stack = rand(seed, (k, d, r))
    w = rand(seed + 1, (k,))
    np.testing.assert_allclose(
        combine(stack, w), ref.combine_ref(stack, w), rtol=1e-4, atol=1e-5
    )


def test_combine_zero_weights_padding():
    # Padding semantics: zero-weight neighbors contribute nothing.
    stack = rand(4, (8, 10, 3))
    w = jnp.array([0.5, 0.5, 0, 0, 0, 0, 0, 0], jnp.float32)
    got = combine(stack, w)
    want = 0.5 * stack[0] + 0.5 * stack[1]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_combine_doubly_stochastic_row():
    # A consensus row: convex weights keep the result in the hull.
    stack = jnp.stack([jnp.full((5, 2), float(i)) for i in range(4)])
    w = jnp.array([0.25, 0.25, 0.25, 0.25], jnp.float32)
    got = combine(stack, w)
    np.testing.assert_allclose(got, jnp.full((5, 2), 1.5), rtol=1e-6)
