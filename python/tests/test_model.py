"""L2 model graph: MGS orthonormalization, fused OI step, F-DOT locals."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@settings(max_examples=20, deadline=None)
@given(d=st.sampled_from([6, 10, 20, 32]), r=st.integers(1, 6), seed=st.integers(0, 2**30))
def test_mgs_orthonormal(d, r, seed):
    v = rand(seed, (d, r))
    q = model.mgs_orthonormalize(v)
    np.testing.assert_allclose(np.array(q.T @ q), np.eye(r), atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_mgs_matches_qr_reference(seed):
    v = rand(seed, (20, 5))
    q = model.mgs_orthonormalize(v)
    q_ref = ref.mgs_ref(v)
    np.testing.assert_allclose(np.array(q), np.array(q_ref), atol=1e-3)


def test_mgs_preserves_column_space():
    v = rand(1, (16, 4))
    q = model.mgs_orthonormalize(v)
    # proj of V onto span(Q) equals V
    proj = q @ (q.T @ v)
    np.testing.assert_allclose(np.array(proj), np.array(v), rtol=1e-3, atol=1e-4)


def test_oi_step_converges_to_top_subspace():
    # Run the fused OI step repeatedly; it must find the dominant subspace.
    d, r = 20, 3
    key = jax.random.PRNGKey(7)
    u = jnp.linalg.qr(jax.random.normal(key, (d, d)))[0]
    lam = jnp.array([1.0, 0.9, 0.8] + [0.3 * 0.9**i for i in range(d - r)])
    m = (u * lam) @ u.T
    m = m.astype(jnp.float32)
    q = jnp.linalg.qr(jax.random.normal(key, (d, r)))[0].astype(jnp.float32)
    for _ in range(150):
        (q,) = model.oi_step(m, q)
    truth = u[:, :r]
    overlap = np.linalg.svd(np.array(truth.T @ q), compute_uv=False)
    err = 1 - (overlap**2).mean()
    assert err < 1e-5, err


def test_sdot_step_is_matmul():
    m = rand(2, (20, 20))
    q = rand(3, (20, 5))
    (v,) = model.sdot_step(m, q)
    np.testing.assert_allclose(np.array(v), np.array(m @ q), rtol=1e-4, atol=1e-5)


def test_fdot_locals_compose_to_mq():
    # X_iᵀ Q_i then X_i S reproduces the feature-wise update of eq. (4)
    # when the network sum is exact (single node).
    x = rand(4, (2, 500))
    q = rand(5, (2, 5))
    (z,) = model.fdot_local_fwd(x, q)
    np.testing.assert_allclose(np.array(z), np.array(x.T @ q), rtol=1e-4, atol=1e-5)
    (v,) = model.fdot_local_back(x, z)
    np.testing.assert_allclose(np.array(v), np.array(x @ x.T @ q), rtol=1e-3, atol=1e-4)


def test_gram_op_scaling():
    x = rand(6, (20, 500))
    (m,) = model.gram_op(x)
    np.testing.assert_allclose(np.array(m), np.array(x @ x.T) / 500, rtol=1e-3, atol=1e-6)
