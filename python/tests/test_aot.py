"""AOT export: HLO-text lowering and manifest integrity."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from compile import aot, model


def test_to_hlo_text_produces_parseable_module():
    lowered = jax.jit(model.sdot_step).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32),
        jax.ShapeDtypeStruct((8, 2), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # return_tuple=True → root is a tuple.
    assert "tuple" in text.lower()


def test_manifest_entries_shapes_consistent():
    entries = aot.manifest_entries()
    names = [e[0] for e in entries]
    assert len(names) == len(set(names)), "artifact names must be unique"
    ops = {e[1] for e in entries}
    assert {"sdot_step", "oi_step", "qr_mgs", "gram", "combine"} <= ops
    for name, op, fn, args, shapes in entries:
        assert shapes == [list(a.shape) for a in args]


def test_existing_artifacts_match_manifest(tmp_path):
    # If `make artifacts` has run, every manifest entry's file must exist
    # and contain an HloModule.
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest_path = os.path.join(art, "manifest.json")
    if not os.path.exists(manifest_path):
        return  # fresh checkout — covered by the aot run itself
    with open(manifest_path) as f:
        manifest = json.load(f)
    assert manifest["entries"], "manifest must not be empty"
    for e in manifest["entries"]:
        p = os.path.join(art, e["file"])
        assert os.path.exists(p), p
        with open(p) as f:
            head = f.read(200)
        assert "HloModule" in head


def test_aot_main_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    # Subset run would be nicer, but the full export is < 2 min and is the
    # exact code path `make artifacts` uses.
    proc = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out)],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr
    manifest = json.loads((out / "manifest.json").read_text())
    assert len(manifest["entries"]) >= 10
    for e in manifest["entries"]:
        assert (out / e["file"]).exists()
