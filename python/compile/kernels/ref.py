"""Pure-jnp oracles for the Pallas kernels (build-time correctness only).

Each kernel in this package is checked against these references by
``python/tests/test_kernel.py`` (hypothesis sweeps shapes/dtypes and
asserts allclose) before the AOT artifacts are emitted.
"""

import jax.numpy as jnp


def matmul_ref(m, q):
    """V = M @ Q — the S-DOT step-5 product (Alg. 1)."""
    return jnp.dot(m, q, preferred_element_type=jnp.float32)


def gram_ref(x):
    """Sample covariance M = X Xᵀ / n for X ∈ R^{d×n} (mean removed)."""
    n = x.shape[1]
    return jnp.dot(x, x.T, preferred_element_type=jnp.float32) / n


def combine_ref(stack, w):
    """Weighted neighbor combine: Z = Σ_k w_k · stack[k]  (consensus op)."""
    return jnp.einsum("k,kdr->dr", w, stack)


def mgs_ref(v):
    """Modified Gram–Schmidt Q factor via jnp.linalg.qr (reference only —
    the lowered model uses the loop form in model.py)."""
    q, r = jnp.linalg.qr(v)
    # Positive-diagonal convention so Q is unique.
    signs = jnp.sign(jnp.diagonal(r))
    signs = jnp.where(signs == 0, 1.0, signs)
    return q * signs[None, :]
