"""L1 Pallas kernel: tiled matmul ``V = M @ Q`` — the S-DOT hot spot.

The O(d²r) product of Alg. 1 step 5 dominates every outer iteration. TPU
mapping (DESIGN.md §Hardware-Adaptation): `M` is streamed through VMEM in
``(bm, bk)`` tiles over a ``(d/bm, d/bk)`` grid while the skinny ``Q``
(r ≤ 16) keeps a full ``(bk, r)`` tile resident; the ``(bm, r)``
accumulator lives in the output block across the contraction steps. The
``interpret=True`` path lowers to plain HLO so the artifact runs on the
PJRT CPU client (real TPU lowering would emit a Mosaic custom-call).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(m_ref, q_ref, o_ref):
    # Zero the accumulator on the first contraction step.
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        m_ref[...], q_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("bm", "bk"))
def matmul(m, q, *, bm=None, bk=None):
    """``m @ q`` via the tiled Pallas kernel (interpret mode).

    Block sizes must divide the corresponding dims; defaults pick the
    largest divisor ≤ 128.
    """
    d_out, d_in = m.shape
    _, r = q.shape
    bm = bm or _default_block(d_out)
    bk = bk or _default_block(d_in)
    assert d_out % bm == 0 and d_in % bk == 0, (m.shape, bm, bk)
    grid = (d_out // bm, d_in // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bk, r), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((bm, r), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((d_out, r), q.dtype),
        interpret=True,
    )(m, q)


def _default_block(dim, cap=1024):
    """Largest divisor of ``dim`` that is ≤ cap.

    Perf note (§Perf, L1 iteration log): interpret-mode Pallas pays ~1 ms
    of while-loop overhead per grid step on CPU-PJRT, so the AOT artifacts
    use the largest block that still fits VMEM. For every shipped shape
    (d ≤ 784, r ≤ 8) a single (d, d) tile double-buffers inside 16 MiB —
    2·(784²·4 B) ≈ 4.9 MiB — so cap=1024 is TPU-legal too; the
    `vmem_footprint_bytes` test enforces this for all artifact shapes.
    """
    best = 1
    for b in range(1, min(dim, cap) + 1):
        if dim % b == 0:
            best = b
    return best


def vmem_footprint_bytes(d, r, bm, bk, dtype_bytes=4):
    """Estimated VMEM residency for one grid step (DESIGN.md §Perf):
    one M tile + one Q tile + the accumulator, double-buffered inputs."""
    m_tile = bm * bk * dtype_bytes
    q_tile = bk * r * dtype_bytes
    acc = bm * r * dtype_bytes
    return 2 * (m_tile + q_tile) + acc
