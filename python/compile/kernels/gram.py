"""L1 Pallas kernel: tiled Gram ``M = X Xᵀ / n`` (local covariance build).

Runs once per node before the iterations start (the paper notes `M_i` is
precomputed), but it is the largest single computation in the stack for
wide data, so it gets the same VMEM-tiled treatment: grid
``(d/bm, d/bn, n/bk)`` with the contraction over samples innermost.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _default_block


def _gram_kernel(xa_ref, xb_ref, o_ref, *, inv_n):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += (
        jnp.dot(xa_ref[...], xb_ref[...].T, preferred_element_type=o_ref.dtype)
        * inv_n
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gram(x, *, bm=None, bn=None, bk=None):
    """``x @ x.T / n`` via the tiled Pallas kernel (interpret mode)."""
    d, n = x.shape
    bm = bm or _default_block(d)
    bn = bn or _default_block(d)
    bk = bk or _default_block(n, cap=1024)
    assert d % bm == 0 and d % bn == 0 and n % bk == 0, (x.shape, bm, bn, bk)
    grid = (d // bm, d // bn, n // bk)
    kernel = functools.partial(_gram_kernel, inv_n=1.0 / n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, d), x.dtype),
        interpret=True,
    )(x, x)
