"""L1 Pallas kernels (build-time only; interpret=True for CPU-PJRT)."""
