"""L1 Pallas kernel: weighted neighbor combine ``Z = Σ_k w_k · stack[k]``.

One consensus-averaging round at a node is a weighted sum of its own and
its neighbors' matrices (Alg. 1 step 9). The stack is padded to a fixed
neighbor count K (zero weights for absent neighbors), making the shape
static for AOT. Grid iterates over K, accumulating into the output block.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(stack_ref, w_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # stack_ref block is (1, d, r); w_ref block is (1,).
    o_ref[...] += w_ref[0] * stack_ref[0]


@jax.jit
def combine(stack, w):
    """``einsum('k,kdr->dr', w, stack)`` via Pallas (interpret mode)."""
    k, d, r = stack.shape
    return pl.pallas_call(
        _combine_kernel,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, d, r), lambda i: (i, 0, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((d, r), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d, r), stack.dtype),
        interpret=True,
    )(stack, w)
