"""L2 — the per-node compute graph of the DPSA stack, in JAX.

These are the functions `python/compile/aot.py` lowers to HLO text for the
Rust runtime. Each calls the L1 Pallas kernels where the paper's hot spot
lives; orthonormalization uses an explicit Modified Gram–Schmidt loop (pure
HLO ops — `jnp.linalg.qr` would lower to a LAPACK custom-call the PJRT CPU
client of xla_extension 0.5.1 cannot run from a text round-trip).
"""

import jax
import jax.numpy as jnp

from .kernels.combine import combine
from .kernels.gram import gram
from .kernels.matmul import matmul


def mgs_orthonormalize(v):
    """Thin QR Q-factor via Modified Gram–Schmidt (fori_loop form).

    Matches `linalg::qr::mgs_qr` on the Rust side: columns are normalized in
    order and later columns are orthogonalized against each finished one,
    with a positive-diagonal convention implied by the normalization.
    """
    d, r = v.shape

    def body(k, acc):
        col = jax.lax.dynamic_slice(acc, (0, k), (d, 1))
        norm = jnp.sqrt(jnp.sum(col * col))
        qk = col / jnp.maximum(norm, 1e-30)
        acc = jax.lax.dynamic_update_slice(acc, qk, (0, k))
        # Subtract the projection of every *later* column onto qk.
        dots = (qk.T @ acc)[0]  # (r,)
        mask = jnp.arange(r) > k
        acc = acc - qk @ jnp.where(mask, dots, 0.0)[None, :]
        return acc

    return jax.lax.fori_loop(0, r, body, v)


def sdot_step(m, q):
    """Alg. 1 step 5: the local product `V = M_i Q` (Pallas matmul)."""
    return (matmul(m, q),)


def oi_step(m, q):
    """One fused orthogonal-iteration update: `Q' = MGS(M Q)`.

    Fusing keeps the request path at a single PJRT execution per node per
    outer iteration (see DESIGN.md §Perf, L2 target).
    """
    return (mgs_orthonormalize(matmul(m, q)),)


def qr_mgs(v):
    """Standalone orthonormalization (Alg. 1 step 12)."""
    return (mgs_orthonormalize(v),)


def gram_op(x):
    """Local covariance `M_i = X_i X_iᵀ / n_i` (Pallas gram kernel)."""
    return (gram(x),)


def combine_op(stack, w):
    """One consensus combine `Z = Σ_k w_k stack[k]` (Pallas kernel)."""
    return (combine(stack, w),)


def fdot_local_fwd(x, q):
    """F-DOT step 5: `Z_i = X_iᵀ Q_i` (n×r) — matmul with X transposed."""
    return (matmul(x.T, q),)


def fdot_local_back(x, z):
    """F-DOT step 11: `V_i = X_i Ẑ_i` (d_i×r)."""
    return (matmul(x, z),)
