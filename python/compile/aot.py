"""AOT export: lower the L2 JAX functions to HLO **text** artifacts.

Interchange is HLO text, not serialized HloModuleProto: jax ≥ 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 (behind the
`xla` crate) rejects; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/load_hlo and the repo DESIGN.md §5).

Usage:  cd python && python -m compile.aot [--out-dir ../artifacts]

Python runs exactly once (`make artifacts` skips when outputs are newer
than inputs); the Rust binary is self-contained afterwards.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def manifest_entries():
    """(name, op, fn, arg specs) for every artifact.

    Shape set: the paper's synthetic config (d=20, r=5, n_i=500), a medium
    config for tests (d=64, r=8), and the MNIST-surrogate hot path
    (d=784, r=5). Consensus combine is padded to K=8 neighbors.
    """
    entries = []

    def add(op, fn, *args, tag=""):
        shapes = [list(a.shape) for a in args]
        name = f"{op}_" + "_".join("x".join(str(d) for d in a.shape) for a in args)
        if tag:
            name = f"{name}_{tag}"
        entries.append((name, op, fn, args, shapes))

    for d, r in [(20, 5), (64, 8), (784, 5)]:
        add("sdot_step", model.sdot_step, spec(d, d), spec(d, r))
        add("oi_step", model.oi_step, spec(d, d), spec(d, r))
        add("qr_mgs", model.qr_mgs, spec(d, r))

    for d, n in [(20, 500), (64, 256)]:
        add("gram", model.gram_op, spec(d, n))

    add("combine", model.combine_op, spec(8, 20, 5), spec(8))
    # F-DOT locals for the Fig.-6-style config: d_i=2 features, n=500.
    add("fdot_fwd", model.fdot_local_fwd, spec(2, 500), spec(2, 5))
    add("fdot_back", model.fdot_local_back, spec(2, 500), spec(500, 5))
    return entries


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": 1, "entries": []}
    for name, op, fn, arg_specs, shapes in manifest_entries():
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        manifest["entries"].append(
            {"name": name, "op": op, "file": fname, "shapes": shapes, "dtype": "f32"}
        )
        print(f"wrote {fname} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['entries'])} entries -> {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
